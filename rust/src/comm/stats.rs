//! Exact communication accounting, per rank and per phase.
//!
//! Two kinds of numbers are tracked for every collective call:
//!
//! * **Volume**: total messages and bytes *sent by this rank* — exact
//!   counts of what crossed rank boundaries. These regenerate Table I
//!   (communication volume of K and Dᵀ computation per algorithm).
//! * **Critical path**: the α-β terms of the collective's schedule —
//!   `rounds` (latency hops on the critical path) and `crit_bytes`
//!   (bytes serialized on the critical path). The machine model
//!   ([`crate::model`]) turns these into modeled communication time:
//!   `T = rounds·α + crit_bytes·β`, mirroring the paper's cost analysis.

use std::collections::BTreeMap;

/// Counters for one phase (e.g. "gemm", "spmm", "update", "redist").
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct PhaseStats {
    /// Messages sent by this rank.
    pub msgs: u64,
    /// Bytes sent by this rank.
    pub bytes: u64,
    /// Latency rounds on the critical path (α multiplier).
    pub rounds: u64,
    /// Bytes on the critical path (β multiplier).
    pub crit_bytes: u64,
}

impl PhaseStats {
    pub fn add(&mut self, other: &PhaseStats) {
        self.msgs += other.msgs;
        self.bytes += other.bytes;
        self.rounds += other.rounds;
        self.crit_bytes += other.crit_bytes;
    }

    /// Elementwise max (for critical-path aggregation across ranks).
    pub fn max(&self, other: &PhaseStats) -> PhaseStats {
        PhaseStats {
            msgs: self.msgs.max(other.msgs),
            bytes: self.bytes.max(other.bytes),
            rounds: self.rounds.max(other.rounds),
            crit_bytes: self.crit_bytes.max(other.crit_bytes),
        }
    }
}

/// Fault-injection and recovery counters for one rank's ledger.
///
/// `injected_*` count faults this rank *fired* (it was the plan's
/// victim); `detected_*` count failures this rank *observed* on
/// receive (a peer's crash flag, a bounded-recv deadline, a poisoned
/// payload). `retries` counts recovery replays credited to this rank
/// by a driver (e.g. a checkpoint-restore in `approx::stream`). All
/// are exact and deterministic for a given `FaultPlan` — the fault
/// test wall pins them across thread counts.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct FaultCounters {
    pub injected_crashes: u64,
    pub injected_drops: u64,
    pub injected_delays: u64,
    pub injected_corruptions: u64,
    pub detected_timeouts: u64,
    pub detected_peer_crashes: u64,
    pub detected_corruptions: u64,
    pub retries: u64,
}

impl FaultCounters {
    pub fn add(&mut self, other: &FaultCounters) {
        self.injected_crashes += other.injected_crashes;
        self.injected_drops += other.injected_drops;
        self.injected_delays += other.injected_delays;
        self.injected_corruptions += other.injected_corruptions;
        self.detected_timeouts += other.detected_timeouts;
        self.detected_peer_crashes += other.detected_peer_crashes;
        self.detected_corruptions += other.detected_corruptions;
        self.retries += other.retries;
    }

    /// Elementwise max (critical-path style aggregation).
    pub fn max(&self, other: &FaultCounters) -> FaultCounters {
        FaultCounters {
            injected_crashes: self.injected_crashes.max(other.injected_crashes),
            injected_drops: self.injected_drops.max(other.injected_drops),
            injected_delays: self.injected_delays.max(other.injected_delays),
            injected_corruptions: self.injected_corruptions.max(other.injected_corruptions),
            detected_timeouts: self.detected_timeouts.max(other.detected_timeouts),
            detected_peer_crashes: self.detected_peer_crashes.max(other.detected_peer_crashes),
            detected_corruptions: self.detected_corruptions.max(other.detected_corruptions),
            retries: self.retries.max(other.retries),
        }
    }

    /// Total events of any kind (quick "anything happened?" probe).
    pub fn total(&self) -> u64 {
        self.injected_crashes
            + self.injected_drops
            + self.injected_delays
            + self.injected_corruptions
            + self.detected_timeouts
            + self.detected_peer_crashes
            + self.detected_corruptions
            + self.retries
    }
}

/// Per-rank ledger of [`PhaseStats`] keyed by phase label.
#[derive(Debug, Default, Clone)]
pub struct CommStats {
    phases: BTreeMap<String, PhaseStats>,
    /// Fault/recovery events on this rank (fault injection layer).
    pub faults: FaultCounters,
}

impl CommStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, phase: &str, delta: PhaseStats) {
        self.phases.entry(phase.to_string()).or_default().add(&delta);
    }

    pub fn get(&self, phase: &str) -> PhaseStats {
        self.phases.get(phase).copied().unwrap_or_default()
    }

    pub fn phases(&self) -> impl Iterator<Item = (&str, &PhaseStats)> {
        self.phases.iter().map(|(k, v)| (k.as_str(), v))
    }

    /// Sum over all phases.
    pub fn total(&self) -> PhaseStats {
        let mut t = PhaseStats::default();
        for s in self.phases.values() {
            t.add(s);
        }
        t
    }

    /// Merge another ledger into this one by summation (a rank's
    /// ledgers across mini-batch launches).
    pub fn absorb(&mut self, other: &CommStats) {
        for (k, v) in &other.phases {
            self.phases.entry(k.clone()).or_default().add(v);
        }
        self.faults.add(&other.faults);
    }

    /// Merge by summation (aggregate volume across ranks).
    pub fn merged_sum(all: &[CommStats]) -> CommStats {
        let mut out = CommStats::new();
        for cs in all {
            for (k, v) in &cs.phases {
                out.phases.entry(k.clone()).or_default().add(v);
            }
            out.faults.add(&cs.faults);
        }
        out
    }

    /// Merge by per-phase max (critical path across ranks).
    pub fn merged_max(all: &[CommStats]) -> CommStats {
        let mut out = CommStats::new();
        for cs in all {
            for (k, v) in &cs.phases {
                let e = out.phases.entry(k.clone()).or_default();
                *e = e.max(v);
            }
            out.faults = out.faults.max(&cs.faults);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_total() {
        let mut s = CommStats::new();
        s.record("gemm", PhaseStats { msgs: 2, bytes: 100, rounds: 2, crit_bytes: 50 });
        s.record("gemm", PhaseStats { msgs: 1, bytes: 10, rounds: 1, crit_bytes: 10 });
        s.record("spmm", PhaseStats { msgs: 5, bytes: 7, rounds: 5, crit_bytes: 7 });
        assert_eq!(s.get("gemm").msgs, 3);
        assert_eq!(s.get("gemm").bytes, 110);
        assert_eq!(s.total().msgs, 8);
        assert_eq!(s.get("absent"), PhaseStats::default());
    }

    #[test]
    fn merges() {
        let mut a = CommStats::new();
        a.record("x", PhaseStats { msgs: 1, bytes: 10, rounds: 1, crit_bytes: 10 });
        let mut b = CommStats::new();
        b.record("x", PhaseStats { msgs: 3, bytes: 5, rounds: 3, crit_bytes: 5 });
        let sum = CommStats::merged_sum(&[a.clone(), b.clone()]);
        assert_eq!(sum.get("x").msgs, 4);
        assert_eq!(sum.get("x").bytes, 15);
        let max = CommStats::merged_max(&[a, b]);
        assert_eq!(max.get("x").msgs, 3);
        assert_eq!(max.get("x").bytes, 10);
    }

    #[test]
    fn fault_counters_merge_with_phases() {
        let mut a = CommStats::new();
        a.faults.injected_crashes = 1;
        a.faults.detected_timeouts = 2;
        let mut b = CommStats::new();
        b.faults.detected_peer_crashes = 3;
        b.faults.detected_timeouts = 1;
        let mut acc = a.clone();
        acc.absorb(&b);
        assert_eq!(acc.faults.injected_crashes, 1);
        assert_eq!(acc.faults.detected_timeouts, 3);
        assert_eq!(acc.faults.detected_peer_crashes, 3);
        let sum = CommStats::merged_sum(&[a.clone(), b.clone()]);
        assert_eq!(sum.faults.total(), 1 + 2 + 3 + 1);
        let max = CommStats::merged_max(&[a, b]);
        assert_eq!(max.faults.detected_timeouts, 2);
        assert_eq!(max.faults.detected_peer_crashes, 3);
        assert_eq!(FaultCounters::default().total(), 0);
    }
}
