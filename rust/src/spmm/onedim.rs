//! 1D B-stationary SpMM (Algorithm 1, lines 4–5).
//!
//! V is replicated by a single Allgather of the assignment vectors
//! (u32 row indices only — the paper's §V wire format); each rank then
//! multiplies the full V against its block row of K. Perfect load
//! balance (every rank's local SpMM touches exactly n·m_p entries) and
//! no movement of K, but the O(n) allgather volume does not shrink
//! with P — Eq. (15).

use crate::backend::ComputeBackend;
use crate::comm::{Comm, Group};
use crate::dense::DenseMatrix;

/// One 1D SpMM: returns E_local (m_p × k) for this rank's points.
///
/// `k_block_row`: K[1D block p, :] (m_p × n). `local_assign`: this
/// rank's slice of the assignment vector. `inv_sizes`: 1/|L_a| (from
/// the global cluster sizes).
pub fn spmm_1d(
    comm: &Comm,
    world: &Group,
    k_block_row: &DenseMatrix,
    local_assign: &[u32],
    k: usize,
    inv_sizes: &[f32],
    backend: &dyn ComputeBackend,
) -> DenseMatrix {
    comm.set_phase("spmm");
    // Allgather V: row indices only (u32), n words total.
    let all_assign = comm.allgather_concat(world, local_assign.to_vec());
    debug_assert_eq!(all_assign.len(), k_block_row.cols());
    backend.spmm_vk(k_block_row, &all_assign, k, inv_sizes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::comm::World;
    use crate::sparse::VPartition;
    use crate::util::{part, rng::Rng};

    /// Single-rank oracle: E = (V·K)ᵀ as points×k.
    fn oracle_e(k_full: &DenseMatrix, assign: &[u32], k: usize) -> DenseMatrix {
        let sizes = {
            let mut s = vec![0u64; k];
            for &a in assign {
                s[a as usize] += 1;
            }
            s
        };
        let inv = VPartition::inv_sizes(&sizes);
        crate::sparse::ops::spmm_vk(k_full, assign, k, &inv)
    }

    #[test]
    fn matches_oracle() {
        let mut rng = Rng::new(51);
        let n = 40;
        let k = 4;
        // Symmetric K like the real pipeline produces.
        let pts = DenseMatrix::random(n, 6, &mut rng);
        let k_full = crate::dense::ops::matmul_nt(&pts, &pts);
        let assign: Vec<u32> = (0..n).map(|_| rng.below(k) as u32).collect();
        let expect = oracle_e(&k_full, &assign, k);
        let sizes = {
            let mut s = vec![0u64; k];
            for &a in &assign {
                s[a as usize] += 1;
            }
            s
        };
        let inv = VPartition::inv_sizes(&sizes);

        for p in [1usize, 2, 4, 5] {
            let kref = &k_full;
            let aref = &assign;
            let iref = &inv;
            let (blocks, stats) = World::run(p, |comm| {
                let world = Group::world(p);
                let (lo, hi) = part::bounds(n, p, comm.rank());
                let be = NativeBackend::new();
                spmm_1d(comm, &world, &kref.row_block(lo, hi), &aref[lo..hi], k, iref, &be)
            });
            let e_full = DenseMatrix::vstack(&blocks);
            assert!(e_full.max_abs_diff(&expect) < 1e-4, "p={p}");
            // Volume: the allgather moves ≈ (P-1)·n u32 words in total
            // (ring), i.e. it does NOT shrink as P grows.
            if p > 1 {
                let total: u64 = stats.iter().map(|s| s.get("spmm").bytes).sum();
                let approx = ((p - 1) * n * 4) as u64;
                assert!(total >= approx / 2 && total <= approx * 2, "p={p} total={total}");
            }
        }
    }
}
