//! Datasets: synthetic generators, paper-dataset stand-ins, and a
//! libSVM-format reader.
//!
//! The paper evaluates on three libSVM datasets (Table II): KDD-sampled
//! (8.4M × 10000), HIGGS (11M × 28), MNIST8m (8.1M × 784). Those files
//! are not available on this testbed, so [`datasets`] provides
//! generators that match each dataset's **feature dimensionality and
//! cluster structure class** at configurable scaled-down n — the
//! algorithms' cost structure depends only on (n, d, k) and V's
//! sparsity, all preserved (see DESIGN.md §1). [`libsvm`] reads the
//! real files if present, so they drop in transparently.

pub mod synth;
pub mod datasets;
pub mod landmarks;
pub mod libsvm;
pub mod stream;

use crate::dense::DenseMatrix;
use crate::sparse::CsrMatrix;

/// A labeled dataset (labels are generator ground truth where
/// available, used only by quality metrics — never by the algorithms).
#[derive(Debug, Clone)]
pub struct Dataset {
    pub points: DenseMatrix,
    /// Ground-truth labels (empty when unknown).
    pub labels: Vec<u32>,
    pub name: String,
}

impl Dataset {
    pub fn n(&self) -> usize {
        self.points.rows()
    }

    pub fn d(&self) -> usize {
        self.points.cols()
    }
}

/// [`Dataset`]'s CSR twin: points held row-sparse with no densify step
/// (the Popcorn lane's input). Memory ∝ nnz, never ∝ n·d.
#[derive(Debug, Clone)]
pub struct SparseDataset {
    pub points: CsrMatrix,
    /// Ground-truth labels (empty when unknown).
    pub labels: Vec<u32>,
    pub name: String,
}

impl SparseDataset {
    pub fn n(&self) -> usize {
        self.points.rows()
    }

    pub fn d(&self) -> usize {
        self.points.cols()
    }

    pub fn nnz(&self) -> usize {
        self.points.nnz()
    }
}

/// A borrowed block of points in either storage — what the landmark
/// gram pipelines and the stream driver are generic over. The dense
/// arm is the existing path, bit for bit; the sparse arm routes to the
/// nnz-bounded kernels.
#[derive(Debug, Clone, Copy)]
pub enum PointsRef<'a> {
    Dense(&'a DenseMatrix),
    Sparse(&'a CsrMatrix),
}

impl<'a> PointsRef<'a> {
    pub fn rows(&self) -> usize {
        match self {
            PointsRef::Dense(m) => m.rows(),
            PointsRef::Sparse(m) => m.rows(),
        }
    }

    pub fn dim(&self) -> usize {
        match self {
            PointsRef::Dense(m) => m.cols(),
            PointsRef::Sparse(m) => m.cols(),
        }
    }

    pub fn is_sparse(&self) -> bool {
        matches!(self, PointsRef::Sparse(_))
    }

    /// Stored entries (dense rows count every element).
    pub fn nnz(&self) -> u64 {
        match self {
            PointsRef::Dense(m) => (m.rows() * m.cols()) as u64,
            PointsRef::Sparse(m) => m.nnz() as u64,
        }
    }

    /// Per-row squared norms; the sparse arm is bit-identical to the
    /// dense one on densifiable data (see [`CsrMatrix::row_sq_norms`]).
    pub fn row_sq_norms(&self) -> Vec<f32> {
        match self {
            PointsRef::Dense(m) => m.row_sq_norms(),
            PointsRef::Sparse(m) => m.row_sq_norms(),
        }
    }

    /// Gather `idx` rows densely (landmark extraction: m ≪ n rows).
    pub fn gather_rows(&self, idx: &[usize]) -> DenseMatrix {
        match self {
            PointsRef::Dense(m) => {
                let mut out = DenseMatrix::zeros(idx.len(), m.cols().max(1));
                for (r, &i) in idx.iter().enumerate() {
                    out.row_mut(r).copy_from_slice(m.row(i));
                }
                out
            }
            PointsRef::Sparse(m) => m.gather_rows(idx),
        }
    }

    /// Rows `lo..hi` as an owned block in the same storage.
    pub fn row_block(&self, lo: usize, hi: usize) -> PointBlock {
        match self {
            PointsRef::Dense(m) => PointBlock::Dense(m.row_block(lo, hi)),
            PointsRef::Sparse(m) => PointBlock::Sparse(m.row_block(lo, hi)),
        }
    }
}

/// An owned block of points in either storage (see [`PointsRef`]).
#[derive(Debug, Clone)]
pub enum PointBlock {
    Dense(DenseMatrix),
    Sparse(CsrMatrix),
}

impl PointBlock {
    pub fn as_ref(&self) -> PointsRef<'_> {
        match self {
            PointBlock::Dense(m) => PointsRef::Dense(m),
            PointBlock::Sparse(m) => PointsRef::Sparse(m),
        }
    }

    pub fn rows(&self) -> usize {
        self.as_ref().rows()
    }

    pub fn dim(&self) -> usize {
        self.as_ref().dim()
    }

    pub fn is_sparse(&self) -> bool {
        matches!(self, PointBlock::Sparse(_))
    }
}
