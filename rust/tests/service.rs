//! Service wall: the multi-tenant stream service end to end.
//!
//! Pins the three serving invariants the runtime promises:
//!
//! * snapshot → restore → ingest is **bit-identical** to never
//!   snapshotting, at p ∈ {1, 4} and both landmark layouts (exact `==`
//!   on assignments, objective curve, and carried sums);
//! * classify-only (`inner_iters` 0) leaves the carried model bitwise
//!   untouched, while one inner iteration moves it;
//! * admission control is the closed form
//!   (`model::analytic::tenant_state_bytes`): an over-budget open is
//!   rejected loudly with the feasibility report while in-budget
//!   tenants keep serving — and the script driver's output is
//!   identical at every worker-thread count.

use vivaldi::approx::stream::{StreamConfig, StreamSession};
use vivaldi::approx::{ApproxConfig, LandmarkLayout};
use vivaldi::backend::NativeBackend;
use vivaldi::data::{synth, PointBlock};
use vivaldi::dense::DenseMatrix;
use vivaldi::runtime::tenants::{run_script, TenantService, TenantSpec};

fn cfg(layout: LandmarkLayout, inner: Vec<usize>) -> StreamConfig {
    StreamConfig {
        base: ApproxConfig { k: 2, m: 16, max_iters: 10, layout, ..Default::default() },
        batch: 64,
        inner_iters: inner,
        ..Default::default()
    }
}

fn batches(points: &DenseMatrix, batch: usize) -> Vec<DenseMatrix> {
    let mut out = Vec::new();
    let mut lo = 0;
    while lo < points.rows() {
        let hi = (lo + batch).min(points.rows());
        out.push(points.row_block(lo, hi));
        lo = hi;
    }
    out
}

#[test]
fn snapshot_restore_is_bit_identical_across_layouts_and_ranks() {
    let backend = NativeBackend::new();
    let data = synth::gaussian_blobs(192, 4, 2, 4.0, 23);
    for layout in [LandmarkLayout::OneD, LandmarkLayout::OneFiveD] {
        for p in [1usize, 4] {
            let c = cfg(layout, vec![]);
            let blocks = batches(&data.points, c.batch);
            assert_eq!(blocks.len(), 3);

            // Reference: one session, never snapshotted.
            let mut full = StreamSession::new(p, c.clone()).unwrap();
            for b in &blocks {
                full.push_batch(PointBlock::Dense(b.clone()), &backend).unwrap();
            }
            let (full_sums, full_weights) = {
                let (s, w) = full.carried_sums().unwrap();
                (s.to_vec(), w.to_vec())
            };
            let full_fit = full.finish().unwrap();

            // Snapshot after the first batch, restore, push the rest.
            let mut head = StreamSession::new(p, c.clone()).unwrap();
            head.push_batch(PointBlock::Dense(blocks[0].clone()), &backend).unwrap();
            let snap = head.snapshot().unwrap();
            let mut tail = StreamSession::restore(c.clone(), &snap).unwrap();
            for b in &blocks[1..] {
                tail.push_batch(PointBlock::Dense(b.clone()), &backend).unwrap();
            }
            let (tail_sums, tail_weights) = {
                let (s, w) = tail.carried_sums().unwrap();
                (s.to_vec(), w.to_vec())
            };
            let tail_fit = tail.finish().unwrap();

            let what = format!("{layout:?} p={p}");
            assert_eq!(full_sums, tail_sums, "carried sums must be bitwise equal ({what})");
            assert_eq!(full_weights, tail_weights, "carried weights ({what})");
            assert_eq!(
                &full_fit.assignments[c.batch..],
                &tail_fit.assignments[..],
                "post-restore assignments ({what})"
            );
            assert_eq!(
                &full_fit.objective_curve[1..],
                &tail_fit.objective_curve[..],
                "post-restore objective curve ({what})"
            );
        }
    }
}

#[test]
fn classify_only_is_frozen_while_one_iteration_moves() {
    let backend = NativeBackend::new();
    let data = synth::gaussian_blobs(128, 4, 2, 4.0, 31);
    let blocks = batches(&data.points, 64);
    let run = |inner: Vec<usize>| {
        let mut sess = StreamSession::new(1, cfg(LandmarkLayout::OneD, inner)).unwrap();
        sess.push_batch(PointBlock::Dense(blocks[0].clone()), &backend).unwrap();
        let warm: Vec<f32> = sess.carried_sums().unwrap().0.to_vec();
        sess.push_batch(PointBlock::Dense(blocks[1].clone()), &backend).unwrap();
        let after: Vec<f32> = sess.carried_sums().unwrap().0.to_vec();
        (warm, after)
    };
    let (warm0, after0) = run(vec![2, 0]);
    let (warm1, after1) = run(vec![2, 1]);
    assert_eq!(warm0, warm1, "identical first batch must leave identical warm sums");
    assert_eq!(after0, warm0, "a 0-iteration batch must leave the sums bitwise unchanged");
    assert_ne!(after1, warm1, "a 1-iteration batch must fold the new batch in");
}

#[test]
fn admission_is_the_closed_form_and_over_budget_opens_reject_loudly() {
    let spec = TenantSpec {
        p: 1,
        d: 4,
        pinned: false,
        cfg: StreamConfig {
            base: ApproxConfig { k: 2, m: 8, max_iters: 10, ..Default::default() },
            batch: 32,
            window: 2,
            ..Default::default()
        },
    };
    let one = vivaldi::model::analytic::tenant_state_bytes(8, 4, 32, 1, 2, 2);
    assert_eq!(spec.state_bytes(), one, "the admission charge is the analytic closed form");

    let mut svc = TenantService::new(Some(one + one / 2));
    let a = svc.open("a", spec.clone()).unwrap();
    assert!(a.admitted);
    assert_eq!(a.tenant_bytes, one);
    let b = svc.open("b", spec.clone()).unwrap();
    assert!(!b.admitted, "the second open exceeds the budget and must be rejected");
    assert_eq!(b.remaining(), one / 2);
    assert_eq!(svc.rejected_opens(), 1);

    // The in-budget tenant keeps serving through the rejection.
    let ds = synth::gaussian_blobs(64, 4, 2, 4.0, 7);
    let rep = svc.ingest("a", ds.points).unwrap();
    assert_eq!((rep.points, rep.batches), (64, 2));
    let q = synth::gaussian_blobs(16, 4, 2, 4.0, 8);
    assert_eq!(svc.classify("a", &q.points).unwrap().points, 16);

    // The script driver prints the verdict plus the feasibility rows.
    let script = "\
budget 1024
open tiny k=2 m=8 d=4 batch=32 window=2
";
    let out = run_script(script, 1, None).unwrap();
    assert!(
        out.iter().any(|l| l.contains("open tiny: REJECTED")),
        "missing rejection line in {out:?}"
    );
    assert!(
        out.iter().any(|l| l.contains("feasibility @")),
        "rejection must carry the feasibility report: {out:?}"
    );
    assert!(
        out.iter().any(|l| l.contains("stream 1.5D windowed")),
        "windowed spec must print the windowed feasibility row: {out:?}"
    );
    assert!(out.last().unwrap().ends_with("rejected opens: 1"));
}

#[test]
fn script_output_is_identical_at_every_thread_count() {
    let script = "\
budget 100000000
open a k=2 m=16 d=4 batch=64 iters=10 seed=5
open b k=2 m=16 d=4 batch=64 iters=10 layout=1.5d p=4 seed=6
open c k=2 m=8 d=4 batch=32 iters=5 inner=2,1 seed=7
ingest a n=128 seed=40
ingest b n=128 seed=41
ingest c n=64 seed=42
snapshot a
classify a n=32 seed=43
restore a
ingest a n=64 seed=44
snapshot b
restore b
ingest b n=64 seed=45
close c
";
    let one = run_script(script, 1, None).unwrap();
    for threads in [2usize, 3, 5] {
        let t = run_script(script, threads, None).unwrap();
        assert_eq!(one, t, "output must not depend on the worker count ({threads} threads)");
    }
    assert!(one.iter().any(|l| l.starts_with("snapshot a: ") && l.ends_with("bytes (v1)")));
    assert!(one.iter().any(|l| l.starts_with("restore b: ")));
    assert!(one.iter().any(|l| l.starts_with("tenant c:") && l.ends_with("closed")));
}
