//! Structured sparse kernels exploiting V's one-nonzero-per-column
//! shape (the cuSPARSE SpMM/SpMV stand-ins).
//!
//! Layout note: a local K tile is stored row-major with **rows = local
//! points j** (columns of Eᵀ) and **cols = the points r that V sums
//! over**. The output E_local is (local points × k) row-major, which is
//! Eᵀ stored column-major — so the 1.5D reduce-scatter's column split
//! is a contiguous memory split (the paper needs an explicit row→column
//! major conversion here; our layout gets it for free, §V.C).

use crate::dense::DenseMatrix;
use crate::util::par::{par_ranges, SendPtr};

/// E_local = (V·K_tile)ᵀ, structured SpMM.
///
/// `k_tile`: (m local points × n_r summed points), row-major.
/// `assign_r[r]`: cluster of summed point r (the one nonzero in column
/// r of V). `inv_sizes[a]` = 1/|L_a| (0 for empty clusters).
///
/// Returns E_local (m × k): `E[j,a] = inv_sizes[a] · Σ_{r: a_r=a} K[j,r]`.
///
/// Work is exactly `m·n_r` multiply-adds regardless of the assignment —
/// the perfect load balance the paper gets from V's structure.
pub fn spmm_vk(k_tile: &DenseMatrix, assign_r: &[u32], k: usize, inv_sizes: &[f32]) -> DenseMatrix {
    assert_eq!(k_tile.cols(), assign_r.len(), "spmm_vk: assignment length");
    assert_eq!(inv_sizes.len(), k, "spmm_vk: inv_sizes length");
    debug_assert!(assign_r.iter().all(|&a| (a as usize) < k));
    let m = k_tile.rows();
    let mut e = DenseMatrix::zeros(m, k);
    {
        let eptr = SendPtr(e.data_mut().as_mut_ptr());
        par_ranges(m, 8, |lo, hi| {
            let eptr = &eptr;
            for j in lo..hi {
                let krow = k_tile.row(j);
                // SAFETY: row j of E is exclusive to this worker.
                let erow = unsafe { std::slice::from_raw_parts_mut(eptr.0.add(j * k), k) };
                // Segment-sum: one pass over the K row.
                for (r, &v) in krow.iter().enumerate() {
                    erow[assign_r[r] as usize] += v;
                }
                for (a, s) in erow.iter_mut().zip(inv_sizes) {
                    *a *= s;
                }
            }
        });
    }
    e
}

/// Eᵀ_partial = V·K_tile with the tile in its *natural* 2D orientation
/// (rows = summed points r, cols = output points j) — the form the
/// grid algorithms hold K in.
///
/// Returns Eᵀ (k × m) row-major:
/// `Eᵀ[a,j] = inv_sizes[a] · Σ_{r: a_r=a} K[r,j]`.
///
/// The (k × m) row-major output is what the 2D algorithm
/// reduce-scatters by cluster blocks; the 1.5D algorithm transposes it
/// to (m × k) first — the row-major→column-major conversion the paper
/// notes in §V.C.
pub fn spmm_vk_t(
    k_tile: &DenseMatrix,
    assign_r: &[u32],
    k: usize,
    inv_sizes: &[f32],
) -> DenseMatrix {
    assert_eq!(k_tile.rows(), assign_r.len(), "spmm_vk_t: assignment length");
    assert_eq!(inv_sizes.len(), k, "spmm_vk_t: inv_sizes length");
    debug_assert!(assign_r.iter().all(|&a| (a as usize) < k));
    let m = k_tile.cols();
    let nr = k_tile.rows();
    let mut et = DenseMatrix::zeros(k, m);
    {
        let eptr = SendPtr(et.data_mut().as_mut_ptr());
        // Parallelize over output-column stripes: every worker walks all
        // K rows but only touches its own column range, so the k×m
        // accumulator rows are written disjointly per stripe.
        par_ranges(m, 256, |lo, hi| {
            let eptr = &eptr;
            for r in 0..nr {
                let a = assign_r[r] as usize;
                let krow = &k_tile.row(r)[lo..hi];
                // SAFETY: columns [lo,hi) of row a are exclusive to this
                // worker.
                let erow =
                    unsafe { std::slice::from_raw_parts_mut(eptr.0.add(a * m + lo), hi - lo) };
                for (e, v) in erow.iter_mut().zip(krow) {
                    *e += v;
                }
            }
            for a in 0..k {
                let s = inv_sizes[a];
                let erow =
                    unsafe { std::slice::from_raw_parts_mut(eptr.0.add(a * m + lo), hi - lo) };
                for e in erow.iter_mut() {
                    *e *= s;
                }
            }
        });
    }
    et
}

/// Partial c = V_local·z_local, structured SpMV.
///
/// `assign[j]` is the cluster of local point j, `z[j] = E[j, cl(j)]`.
/// Returns the local contribution `c_a = inv_sizes[a] · Σ_{j∈L_a} z[j]`
/// (summed across ranks by an allreduce).
pub fn spmv_vz(assign: &[u32], z: &[f32], k: usize, inv_sizes: &[f32]) -> Vec<f32> {
    assert_eq!(assign.len(), z.len());
    assert_eq!(inv_sizes.len(), k);
    let mut c = vec![0.0f32; k];
    for (&a, &zv) in assign.iter().zip(z) {
        c[a as usize] += zv;
    }
    for (ca, s) in c.iter_mut().zip(inv_sizes) {
        *ca *= s;
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::vmatrix::VPartition;
    use crate::util::rng::Rng;

    /// Cross-check the structured SpMM against general CSC SpMM:
    /// (V · K_tileᵀ) == spmm_vk(K_tile)ᵀ.
    #[test]
    fn matches_general_csc_spmm() {
        let mut rng = Rng::new(7);
        for (m, nr, k) in [(5, 8, 3), (16, 16, 4), (9, 31, 5)] {
            let k_tile = DenseMatrix::random(m, nr, &mut rng);
            // Round-robin prefix guarantees every cluster non-empty (the
            // CSC division needs it), so the cross-check always runs —
            // a random assignment could leave a cluster empty and
            // silently skip the oracle.
            let assign: Vec<u32> =
                (0..nr).map(|r| if r < k { r as u32 } else { rng.below(k) as u32 }).collect();
            let v = VPartition::from_assign(k, 0, assign.clone());
            let sizes = v.local_sizes();
            assert!(sizes.iter().all(|&s| s > 0), "prefix must fill every cluster");
            let inv = VPartition::inv_sizes(&sizes);
            let e = spmm_vk(&k_tile, &assign, k, &inv);

            let csc = v.to_csc(&sizes); // k × nr
            let general = csc.spmm(&k_tile.transpose()); // (k×nr)·(nr×m) = k×m
            for j in 0..m {
                for a in 0..k {
                    assert!(
                        (e.get(j, a) - general.get(a, j)).abs() < 1e-4,
                        "({m},{nr},{k}) at ({j},{a})"
                    );
                }
            }
        }
    }

    #[test]
    fn vk_t_is_transpose_consistent_with_vk() {
        // spmm_vk_t(Kᵀ) must equal spmm_vk(K)ᵀ-wise: for symmetric or
        // general tiles, E[j,a] from vk == Eᵀ[a,j] from vk_t on the
        // transposed tile.
        let mut rng = Rng::new(17);
        for (m, nr, k) in [(6, 9, 3), (12, 5, 4)] {
            let k_tile = DenseMatrix::random(m, nr, &mut rng); // m×nr (vk layout)
            let assign: Vec<u32> = (0..nr).map(|_| rng.below(k) as u32).collect();
            let inv: Vec<f32> = (0..k).map(|a| 1.0 / (a + 1) as f32).collect();
            let e = spmm_vk(&k_tile, &assign, k, &inv); // m×k
            let et = spmm_vk_t(&k_tile.transpose(), &assign, k, &inv); // k×m
            for j in 0..m {
                for a in 0..k {
                    assert!((e.get(j, a) - et.get(a, j)).abs() < 1e-5);
                }
            }
        }
    }

    #[test]
    fn spmv_matches_csc() {
        let mut rng = Rng::new(8);
        let n = 23;
        let k = 4;
        // Round-robin prefix: every cluster non-empty by construction,
        // so the CSC cross-check below always executes.
        let assign: Vec<u32> =
            (0..n).map(|r| if r < k { r as u32 } else { rng.below(k) as u32 }).collect();
        let z: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
        let v = VPartition::from_assign(k, 0, assign.clone());
        let sizes = v.local_sizes();
        assert!(sizes.iter().all(|&s| s > 0), "prefix must fill every cluster");
        let inv = VPartition::inv_sizes(&sizes);
        let c = spmv_vz(&assign, &z, k, &inv);
        let expect = v.to_csc(&sizes).spmv(&z);
        for (a, b) in c.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn empty_cluster_contributes_zero() {
        let k_tile = DenseMatrix::from_fn(2, 3, |_, _| 1.0);
        let assign = vec![0u32, 0, 0];
        // Cluster 1 empty -> inv size 0.
        let inv = vec![1.0 / 3.0, 0.0];
        let e = spmm_vk(&k_tile, &assign, 2, &inv);
        assert!((e.get(0, 0) - 1.0).abs() < 1e-6);
        assert_eq!(e.get(0, 1), 0.0);
        assert_eq!(e.get(1, 1), 0.0);
    }

    #[test]
    fn load_is_assignment_independent() {
        // Same K tile, two very skewed assignments -> identical flop
        // count by construction; just verify results differ but both
        // complete with the same shapes.
        let mut rng = Rng::new(9);
        let k_tile = DenseMatrix::random(10, 50, &mut rng);
        let balanced: Vec<u32> = (0..50).map(|r| (r % 5) as u32).collect();
        let skewed: Vec<u32> = vec![0; 50];
        let inv = vec![1.0; 5];
        let e1 = spmm_vk(&k_tile, &balanced, 5, &inv);
        let e2 = spmm_vk(&k_tile, &skewed, 5, &inv);
        assert_eq!(e1.rows(), e2.rows());
        assert_eq!(e1.cols(), e2.cols());
    }
}
