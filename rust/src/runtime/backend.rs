//! [`PjrtBackend`]: ComputeBackend implementation dispatching to AOT
//! artifacts, with transparent native fallback + hit/miss accounting.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::manifest::{Manifest, TensorSpec};
use super::service::{fingerprint_f32, Arg, DeviceService, HostTensor};
use crate::backend::{ComputeBackend, NativeBackend};
use crate::dense::DenseMatrix;
use crate::kernelfn::KernelFn;

/// PJRT-backed compute with native fallback.
pub struct PjrtBackend {
    svc: Arc<DeviceService>,
    native: NativeBackend,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl PjrtBackend {
    /// Load artifacts from the default directory with `n_devices`
    /// service threads.
    pub fn from_default_artifacts(n_devices: usize) -> Result<Self, String> {
        let dir = super::artifacts_dir();
        let manifest = Manifest::load(&dir)?;
        Self::new(&manifest, n_devices)
    }

    pub fn new(manifest: &Manifest, n_devices: usize) -> Result<Self, String> {
        Ok(PjrtBackend {
            svc: Arc::new(DeviceService::start(manifest, n_devices)?),
            native: NativeBackend::new(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        })
    }

    /// (artifact executions, native fallbacks) so far.
    pub fn counters(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }

    pub fn fallbacks(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    fn try_exec(&self, op: &str, inputs: Vec<HostTensor>) -> Option<Vec<HostTensor>> {
        let specs: Vec<_> = inputs.iter().map(|t| t.spec()).collect();
        if !self.svc.has(op, &specs) {
            self.misses.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        match self.svc.execute(op, inputs) {
            Ok(out) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(out)
            }
            Err(e) => {
                // Compiled but failed at run time: surface loudly in
                // debug, fall back in release.
                debug_assert!(false, "pjrt execute failed: {e}");
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Execute an SpMM with the (immutable, iteration-invariant) K tile
    /// kept device-resident: uploaded once per fingerprint, referenced
    /// thereafter — avoids re-copying the tile every iteration.
    fn try_exec_spmm_cached(
        &self,
        op: &str,
        k_tile: &DenseMatrix,
        rest: Vec<HostTensor>,
    ) -> Option<Vec<HostTensor>> {
        let tile_spec =
            TensorSpec { shape: vec![k_tile.rows(), k_tile.cols()], dtype: super::manifest::Dtype::F32 };
        let mut specs = vec![tile_spec.clone()];
        specs.extend(rest.iter().map(|t| t.spec()));
        if !self.svc.has(op, &specs) {
            self.misses.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        let fp = fingerprint_f32(k_tile.data(), &[k_tile.rows(), k_tile.cols()]);
        if !self.svc.has_cached(fp) {
            let t = HostTensor::F32(k_tile.data().to_vec(), vec![k_tile.rows(), k_tile.cols()]);
            if let Err(e) = self.svc.put_cached(fp, t) {
                debug_assert!(false, "pjrt put_cached failed: {e}");
                self.misses.fetch_add(1, Ordering::Relaxed);
                return None;
            }
        }
        let mut args = vec![Arg::Cached { fp, spec: tile_spec }];
        args.extend(rest.into_iter().map(Arg::Inline));
        match self.svc.execute_cached(fp, op, args) {
            Ok(out) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(out)
            }
            Err(e) => {
                debug_assert!(false, "pjrt cached execute failed: {e}");
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    fn mat(t: &HostTensor) -> DenseMatrix {
        match t {
            HostTensor::F32(v, s) => DenseMatrix::from_vec(s[0], s[1], v.clone()),
            _ => panic!("expected f32 matrix"),
        }
    }

    fn assign_i32(assign: &[u32]) -> HostTensor {
        HostTensor::I32(assign.iter().map(|&a| a as i32).collect(), vec![assign.len()])
    }
}

/// Is this the paper's default polynomial kernel (the one baked into
/// the `gram_poly` / `kernel_apply_poly` artifacts)?
fn is_paper_poly(kernel: &KernelFn) -> bool {
    matches!(kernel, KernelFn::Polynomial { gamma, c, degree }
        if *gamma == 1.0 && *c == 1.0 && *degree == 2.0)
}

impl ComputeBackend for PjrtBackend {
    fn gram_tile(
        &self,
        a: &DenseMatrix,
        b: &DenseMatrix,
        kernel: &KernelFn,
        row_norms: &[f32],
        col_norms: &[f32],
    ) -> DenseMatrix {
        if is_paper_poly(kernel) {
            let inputs = vec![
                HostTensor::F32(a.data().to_vec(), vec![a.rows(), a.cols()]),
                HostTensor::F32(b.data().to_vec(), vec![b.rows(), b.cols()]),
            ];
            if let Some(out) = self.try_exec("gram_poly", inputs) {
                return Self::mat(&out[0]);
            }
        }
        self.native.gram_tile(a, b, kernel, row_norms, col_norms)
    }

    fn matmul_nn_acc(&self, a: &DenseMatrix, b: &DenseMatrix, c: &mut DenseMatrix) {
        // SUMMA inner accumulation stays native (shape zoo).
        self.native.matmul_nn_acc(a, b, c)
    }

    fn kernel_apply(
        &self,
        b: &mut DenseMatrix,
        kernel: &KernelFn,
        row_norms: &[f32],
        col_norms: &[f32],
    ) {
        if is_paper_poly(kernel) {
            let inputs = vec![HostTensor::F32(b.data().to_vec(), vec![b.rows(), b.cols()])];
            if let Some(out) = self.try_exec("kernel_apply_poly", inputs) {
                *b = Self::mat(&out[0]);
                return;
            }
        }
        self.native.kernel_apply(b, kernel, row_norms, col_norms)
    }

    fn spmm_vk(
        &self,
        k_tile: &DenseMatrix,
        assign_r: &[u32],
        k: usize,
        inv_sizes: &[f32],
    ) -> DenseMatrix {
        let rest = vec![Self::assign_i32(assign_r), HostTensor::F32(inv_sizes.to_vec(), vec![k])];
        if let Some(out) = self.try_exec_spmm_cached("spmm_vk", k_tile, rest) {
            return Self::mat(&out[0]);
        }
        self.native.spmm_vk(k_tile, assign_r, k, inv_sizes)
    }

    fn spmm_vk_t(
        &self,
        k_tile: &DenseMatrix,
        assign_r: &[u32],
        k: usize,
        inv_sizes: &[f32],
    ) -> DenseMatrix {
        let rest = vec![Self::assign_i32(assign_r), HostTensor::F32(inv_sizes.to_vec(), vec![k])];
        if let Some(out) = self.try_exec_spmm_cached("spmm_vk_t", k_tile, rest) {
            return Self::mat(&out[0]);
        }
        self.native.spmm_vk_t(k_tile, assign_r, k, inv_sizes)
    }

    fn mask_z(&self, e_local: &DenseMatrix, assign: &[u32]) -> Vec<f32> {
        self.native.mask_z(e_local, assign)
    }

    fn spmv_vz(&self, assign: &[u32], z: &[f32], k: usize, inv_sizes: &[f32]) -> Vec<f32> {
        self.native.spmv_vz(assign, z, k, inv_sizes)
    }

    fn update_pre(&self, e_local: &DenseMatrix, assign: &[u32], k: usize, inv_sizes: &[f32]) -> Vec<f32> {
        let inputs = vec![
            HostTensor::F32(e_local.data().to_vec(), vec![e_local.rows(), e_local.cols()]),
            Self::assign_i32(assign),
            HostTensor::F32(inv_sizes.to_vec(), vec![k]),
        ];
        if let Some(out) = self.try_exec("update_pre", inputs) {
            return out[0].as_f32().unwrap().to_vec();
        }
        self.native.update_pre(e_local, assign, k, inv_sizes)
    }

    fn distances_argmin(&self, e_local: &DenseMatrix, c: &[f32]) -> (Vec<u32>, Vec<f32>) {
        let inputs = vec![
            HostTensor::F32(e_local.data().to_vec(), vec![e_local.rows(), e_local.cols()]),
            HostTensor::F32(c.to_vec(), vec![c.len()]),
        ];
        if let Some(out) = self.try_exec("update_post", inputs) {
            let am = out[0].as_i32().unwrap().iter().map(|&x| x as u32).collect();
            let mv = out[1].as_f32().unwrap().to_vec();
            return (am, mv);
        }
        self.native.distances_argmin(e_local, c)
    }

    fn name(&self) -> &str {
        "pjrt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn backend() -> Option<PjrtBackend> {
        if !crate::runtime::artifacts_available() {
            eprintln!("skipping: no artifacts");
            return None;
        }
        Some(PjrtBackend::from_default_artifacts(1).unwrap())
    }

    #[test]
    fn pjrt_matches_native_on_manifest_shapes() {
        let Some(be) = backend() else { return };
        let nat = NativeBackend::new();
        let manifest = Manifest::load(&crate::runtime::artifacts_dir()).unwrap();
        let mut rng = Rng::new(77);
        // For every spmm_vk entry, compare pjrt vs native.
        for entry in manifest.ops.iter().filter(|e| e.op == "spmm_vk") {
            let (m, nr) = (entry.inputs[0].shape[0], entry.inputs[0].shape[1]);
            let k = entry.inputs[2].shape[0];
            if m * nr > 1 << 22 {
                continue; // keep the test fast
            }
            let k_tile = DenseMatrix::random(m, nr, &mut rng);
            let assign: Vec<u32> = (0..nr).map(|_| rng.below(k) as u32).collect();
            let inv: Vec<f32> = (0..k).map(|a| 1.0 / (a + 1) as f32).collect();
            let got = be.spmm_vk(&k_tile, &assign, k, &inv);
            let want = nat.spmm_vk(&k_tile, &assign, k, &inv);
            assert!(got.max_abs_diff(&want) < 1e-3, "{m}x{nr} k={k}");
        }
        let (hits, _) = be.counters();
        assert!(hits > 0, "expected artifact executions");
    }

    #[test]
    fn fallback_counts_unmatched_shapes() {
        let Some(be) = backend() else { return };
        let mut rng = Rng::new(78);
        // Weird shape not in any manifest.
        let k_tile = DenseMatrix::random(13, 29, &mut rng);
        let assign: Vec<u32> = (0..29).map(|_| rng.below(3) as u32).collect();
        let out = be.spmm_vk(&k_tile, &assign, 3, &[0.5, 0.25, 1.0]);
        assert_eq!(out.rows(), 13);
        assert!(be.fallbacks() > 0);
    }

    #[test]
    fn update_post_matches_native() {
        let Some(be) = backend() else { return };
        let nat = NativeBackend::new();
        let manifest = Manifest::load(&crate::runtime::artifacts_dir()).unwrap();
        let mut rng = Rng::new(79);
        let entry = manifest
            .ops
            .iter()
            .filter(|e| e.op == "update_post")
            .min_by_key(|e| e.inputs[0].shape[0])
            .unwrap();
        let (m, k) = (entry.inputs[0].shape[0], entry.inputs[0].shape[1]);
        let e = DenseMatrix::random(m, k, &mut rng);
        let c: Vec<f32> = (0..k).map(|_| rng.next_f32()).collect();
        let (am1, mv1) = be.distances_argmin(&e, &c);
        let (am2, mv2) = nat.distances_argmin(&e, &c);
        assert_eq!(am1, am2);
        for (a, b) in mv1.iter().zip(&mv2) {
            assert!((a - b).abs() < 1e-4);
        }
    }
}
