//! Backend bit-identity wall.
//!
//! The threaded local-compute backend must produce results exactly
//! `==` the pinned single-thread backend — assignments, objective
//! curves, change counts — with **no tolerances**, at every tested
//! thread count, for batch and streaming fits, both landmark layouts,
//! and p ∈ {1, 4}. The identity holds by construction (every threaded
//! kernel assigns each output element to exactly one worker with a
//! fixed inner iteration order), so any `!=` here is a scheduling bug,
//! not float noise.

use vivaldi::approx::stream::{fit_stream_with_backend, StreamConfig};
use vivaldi::approx::{self, ApproxConfig, LandmarkLayout};
use vivaldi::backend::NativeBackend;
use vivaldi::data::stream::MatrixSource;
use vivaldi::data::synth;

const THREADS: [usize; 4] = [1, 2, 4, 8];

fn batch_cfg(layout: LandmarkLayout) -> ApproxConfig {
    ApproxConfig {
        k: 4,
        m: 32,
        layout,
        max_iters: 5,
        converge_on_stable: false,
        ..Default::default()
    }
}

#[test]
fn batch_fit_is_bit_identical_across_thread_counts() {
    let ds = synth::concentric_rings(256, 4, 20260710);
    for layout in [LandmarkLayout::OneD, LandmarkLayout::OneFiveD] {
        for p in [1usize, 4] {
            let cfg = batch_cfg(layout);
            let base = approx::fit_with_backend(p, &ds.points, &cfg, &NativeBackend::scalar())
                .expect("scalar fit");
            for t in THREADS {
                let out =
                    approx::fit_with_backend(p, &ds.points, &cfg, &NativeBackend::threaded(t))
                        .expect("threaded fit");
                let ctx = format!("layout={} p={p} threads={t}", layout.name());
                assert_eq!(out.assignments, base.assignments, "assignments differ: {ctx}");
                assert_eq!(
                    out.objective_curve, base.objective_curve,
                    "objective curve differs: {ctx}"
                );
                assert_eq!(out.changes_curve, base.changes_curve, "changes differ: {ctx}");
                assert_eq!(out.iterations, base.iterations, "iterations differ: {ctx}");
                assert_eq!(out.converged, base.converged, "convergence differs: {ctx}");
            }
        }
    }
}

#[test]
fn stream_fit_is_bit_identical_across_thread_counts() {
    // Windowed drifting stream: exercises init, the inner loop, the
    // carried decayed sums, ring eviction, and the tail classify — the
    // full streaming surface the backend routes through.
    let ds = synth::migrating_blobs(64, 6, 8, 4, 6.0, 3, 20260710);
    for layout in [LandmarkLayout::OneD, LandmarkLayout::OneFiveD] {
        for p in [1usize, 4] {
            let cfg = StreamConfig {
                base: ApproxConfig {
                    k: 4,
                    m: 32,
                    layout,
                    max_iters: 4,
                    converge_on_stable: false,
                    ..Default::default()
                },
                batch: 64,
                window: 2,
                ..Default::default()
            };
            let mut src = MatrixSource::new(&ds.points);
            let base = fit_stream_with_backend(p, &mut src, &cfg, &NativeBackend::scalar())
                .expect("scalar stream fit");
            for t in THREADS {
                let mut src = MatrixSource::new(&ds.points);
                let out =
                    fit_stream_with_backend(p, &mut src, &cfg, &NativeBackend::threaded(t))
                        .expect("threaded stream fit");
                let ctx = format!("layout={} p={p} threads={t}", layout.name());
                assert_eq!(out.assignments, base.assignments, "assignments differ: {ctx}");
                assert_eq!(
                    out.objective_curve, base.objective_curve,
                    "objective curve differs: {ctx}"
                );
                assert_eq!(
                    out.batch_iterations, base.batch_iterations,
                    "inner-iteration schedule differs: {ctx}"
                );
                assert_eq!(out.peak_mem, base.peak_mem, "peak memory differs: {ctx}");
                assert_eq!(out.converged, base.converged, "convergence differs: {ctx}");
            }
        }
    }
}

#[test]
fn threaded_backend_is_deterministic_run_to_run() {
    // Same inputs, same backend, two runs: bit-identical outputs. The
    // thread scheduler must have no observable effect on the numbers.
    let ds = synth::concentric_rings(192, 2, 7);
    let cfg = batch_cfg(LandmarkLayout::OneD);
    let be = NativeBackend::threaded(8);
    let a = approx::fit_with_backend(4, &ds.points, &cfg, &be).expect("first run");
    let b = approx::fit_with_backend(4, &ds.points, &cfg, &be).expect("second run");
    assert_eq!(a.assignments, b.assignments);
    assert_eq!(a.objective_curve, b.objective_curve);
    assert_eq!(a.changes_curve, b.changes_curve);
}

#[test]
fn backend_kind_knob_parses_and_instantiates() {
    use vivaldi::backend::BackendKind;
    assert_eq!(BackendKind::parse("scalar").unwrap(), BackendKind::Scalar);
    assert_eq!(BackendKind::parse("threaded").unwrap(), BackendKind::Threaded);
    assert!(BackendKind::parse("gpu").is_err());
    assert_eq!(BackendKind::Scalar.backend().thread_cap(), 1);
    assert_eq!(BackendKind::Threaded.backend().thread_cap(), 0); // global default
}
