//! Result reporting: aligned console tables, CSV, and JSON records.

use crate::util::json::Json;
use std::collections::BTreeMap;

/// A printable results table (one per paper table/figure series).
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells);
    }

    /// Aligned console rendering.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.headers.join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }

    /// Write CSV under `results/` (created on demand).
    pub fn save_csv(&self, name: &str) -> std::io::Result<std::path::PathBuf> {
        let dir = results_dir();
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(format!("{name}.csv"));
        std::fs::write(&path, self.to_csv())?;
        Ok(path)
    }
}

/// Results directory (override with `VIVALDI_RESULTS`).
pub fn results_dir() -> std::path::PathBuf {
    std::env::var("VIVALDI_RESULTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("results"))
}

/// A flat metric record serializable to JSON (experiment provenance).
#[derive(Debug, Clone, Default)]
pub struct Record {
    fields: BTreeMap<String, Json>,
}

impl Record {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn set_str(&mut self, k: &str, v: &str) -> &mut Self {
        self.fields.insert(k.into(), Json::Str(v.into()));
        self
    }

    pub fn set_num(&mut self, k: &str, v: f64) -> &mut Self {
        self.fields.insert(k.into(), Json::Num(v));
        self
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(self.fields.clone())
    }
}

/// Append records as JSON lines under `results/`.
pub fn append_jsonl(name: &str, records: &[Record]) -> std::io::Result<std::path::PathBuf> {
    use std::io::Write;
    let dir = results_dir();
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("{name}.jsonl"));
    let mut f = std::fs::OpenOptions::new().create(true).append(true).open(&path)?;
    for r in records {
        writeln!(f, "{}", r.to_json().to_string())?;
    }
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["algo", "time"]);
        t.row(vec!["1.5D".into(), "0.5".into()]);
        t.row(vec!["longer-name".into(), "12.25".into()]);
        let s = t.render();
        assert!(s.contains("demo"));
        assert!(s.contains("1.5D"));
        // CSV shape.
        let csv = t.to_csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.starts_with("algo,time"));
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn record_json() {
        let mut r = Record::new();
        r.set_str("algo", "2D").set_num("gpus", 16.0);
        let j = r.to_json().to_string();
        assert!(j.contains("\"algo\":\"2D\""));
        assert!(j.contains("\"gpus\":16"));
    }
}
