//! Minimal JSON reader/writer.
//!
//! Used for the AOT artifact manifest (`artifacts/manifest.json`),
//! experiment configs, and result reports. Supports the full JSON value
//! model except for exotic number forms; numbers are stored as f64
//! (plenty for manifests and metric reports).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Build an object from (key, value) pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(input: &str) -> Result<Json, String> {
    let mut p = Parser { b: input.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?;
        s.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number {s:?}: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5]).unwrap_or("");
                            let cp =
                                u32::from_str_radix(hex, 16).map_err(|e| format!("\\u: {e}"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..]).map_err(|e| e.to_string())?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                other => return Err(format!("expected ',' or ']', got {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                other => return Err(format!("expected ',' or '}}', got {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, "x\n"], "c": {"d": -2.5e3}}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64(), Some(-2500.0));
        let re = parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
        let re2 = parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re2);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(Default::default()));
        assert_eq!(parse("[]").unwrap().to_string(), "[]");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn string_escapes() {
        let v = parse(r#""aA\t\"\\b""#).unwrap();
        assert_eq!(v.as_str(), Some("aA\t\"\\b"));
        let round = parse(&v.to_string()).unwrap();
        assert_eq!(v, round);
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse(r#""héllo ∆""#).unwrap();
        assert_eq!(v.as_str(), Some("héllo ∆"));
    }

    #[test]
    fn integers_stay_integral_in_output() {
        let v = Json::Num(42.0);
        assert_eq!(v.to_string(), "42");
        let v = Json::Num(0.5);
        assert_eq!(v.to_string(), "0.5");
    }
}
