"""AOT pipeline: lower every L2 op at the manifest shapes to HLO text.

HLO *text* (not serialized HloModuleProto) is the interchange format:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the Rust
side's xla_extension 0.5.1 rejects; the text parser reassigns ids, so
text round-trips cleanly (see /opt/xla-example/README.md).

Usage (from ``make artifacts``):

    cd python && python -m compile.aot --out ../artifacts

Emits ``<op>__<shape-sig>.hlo.txt`` per entry plus ``manifest.json``
describing op name, input/output shapes+dtypes, and baked kernel
parameters. The Rust runtime (rust/src/runtime/) compiles each module
once on the PJRT CPU client and dispatches by (op, input shapes).

The default shape set covers the shipped examples and benches; pass
``--shapes custom.json`` to extend it (the Rust backend falls back to
the native path at unmatched shapes, counting misses).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import ref

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def dtype_tag(dt) -> str:
    return {"float32": "f32", "int32": "i32"}[jnp.dtype(dt).name]


def impl_table(impl):
    """Op-name → lowering function for the chosen implementation.

    * ``pallas`` — the L1 Pallas kernels under interpret=True. This is
      the TPU-shaped code path; on CPU the interpreter makes it 5-100×
      slower than XLA-compiled jnp (EXPERIMENTS.md §Perf), so it is the
      *validation* target, not the serving default.
    * ``jnp`` (default) — the pure-jnp reference ops (ref.py), which
      pytest verifies bit-close against the Pallas kernels. XLA fuses
      these into tight CPU loops; this is what the Rust hot path loads.
    """
    if impl == "pallas":
        return {
            "gram_poly": model.gram_tile_poly,
            "kernel_apply_poly": model.kernel_apply_poly,
            "spmm_vk": model.spmm_vk,
            "spmm_vk_t": model.spmm_vk_t,
            "update_pre": model.update_pre,
            "update_post": model.update_post,
        }
    if impl == "jnp":
        return {
            "gram_poly": ref.gram_poly,
            "kernel_apply_poly": ref.kernel_apply_poly,
            "spmm_vk": ref.spmm_vk,
            "spmm_vk_t": ref.spmm_vk_t,
            "update_pre": ref.update_pre,
            "update_post": ref.update_post,
        }
    raise ValueError(f"unknown impl {impl!r}")


def default_entries(n=4096, d=64, k=16, q=2, impl="jnp"):
    """Shape set for the default experiment scale (n, d, k, √P = q).

    Derived sizes: grid block t = n/q, 1D slice m = n/q², 1D block row
    mb = n/p.
    """
    p = q * q
    t = n // q
    m = n // p
    fns = impl_table(impl)
    entries = []

    def add(op, args, params=None):
        entries.append({"op": op, "fn": fns[op], "args": args, "params": params or {}})

    # K computation (1D block row + sliding-window block + SUMMA tile).
    add("gram_poly", [spec((m, d)), spec((n, d))])
    add("gram_poly", [spec((t, d)), spec((n, d))])
    add("gram_poly", [spec((512, d)), spec((n, d))])
    add("gram_poly", [spec((n, d)), spec((n, d))])
    add("kernel_apply_poly", [spec((t, t))])

    # Clustering loop, 1D layout (m × n block rows).
    add("spmm_vk", [spec((m, n)), spec((n,), I32), spec((k,))])
    add("spmm_vk", [spec((512, n)), spec((n,), I32), spec((k,))])
    add("spmm_vk", [spec((n, n)), spec((n,), I32), spec((k,))])
    # Clustering loop, 2D/1.5D tiles (t × t).
    add("spmm_vk_t", [spec((t, t)), spec((t,), I32), spec((k,))])
    # Update steps at the 1D slice (m), tile (t), and full (n) heights.
    for rows in sorted({m, t, n, 512}):
        add("update_pre", [spec((rows, k)), spec((rows,), I32), spec((k,))])
        add("update_post", [spec((rows, k)), spec((k,))])
    return entries


def signature(args) -> str:
    return "_".join("x".join(map(str, a.shape)) + dtype_tag(a.dtype) for a in args)


def lower_entry(entry, out_dir):
    args = entry["args"]
    fn = entry["fn"]
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    sig = signature(args)
    fname = f"{entry['op']}__{sig}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    out_shapes = jax.eval_shape(fn, *args)
    if not isinstance(out_shapes, (tuple, list)):
        out_shapes = (out_shapes,)
    return {
        "op": entry["op"],
        "file": fname,
        "inputs": [
            {"shape": list(a.shape), "dtype": dtype_tag(a.dtype)} for a in args
        ],
        "outputs": [
            {"shape": list(o.shape), "dtype": dtype_tag(o.dtype)} for o in out_shapes
        ],
        "params": entry["params"],
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--d", type=int, default=64)
    ap.add_argument("--k", type=int, default=16)
    ap.add_argument("--q", type=int, default=2)
    ap.add_argument(
        "--impl",
        choices=["jnp", "pallas"],
        default="jnp",
        help="lowering source: jnp = XLA-fused reference (CPU serving "
        "default), pallas = L1 kernels under interpret=True (TPU-shaped; "
        "slow on CPU, for validation)",
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    entries = default_entries(n=args.n, d=args.d, k=args.k, q=args.q, impl=args.impl)
    manifest = {"version": 1, "ops": []}
    seen = set()
    for e in entries:
        key = (e["op"], signature(e["args"]))
        if key in seen:
            continue
        seen.add(key)
        rec = lower_entry(e, args.out)
        manifest["ops"].append(rec)
        print(f"lowered {rec['op']:<18} {rec['file']}")
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {len(manifest['ops'])} artifacts + manifest to {args.out}")


if __name__ == "__main__":
    main()
