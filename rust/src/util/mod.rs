//! Small self-contained utilities: PRNG, JSON, parallel-for, timing.
//!
//! The crate builds fully offline against a vendored dependency set that
//! contains only the `xla` closure, so the usual ecosystem crates
//! (`rand`, `serde_json`, `rayon`, `criterion`) are replaced by the
//! minimal, well-tested implementations in this module.

pub mod rng;
pub mod json;
pub mod par;
pub mod part;
pub mod timing;

/// Integer ceiling division.
#[inline]
pub fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

/// True if `p` is a perfect square.
#[inline]
pub fn is_perfect_square(p: usize) -> bool {
    let r = (p as f64).sqrt().round() as usize;
    r * r == p
}

/// Integer square root of a perfect square (panics otherwise).
#[inline]
pub fn isqrt_exact(p: usize) -> usize {
    let r = (p as f64).sqrt().round() as usize;
    assert_eq!(r * r, p, "{p} is not a perfect square");
    r
}

/// Human-readable byte count (GiB/MiB/KiB/B).
pub fn human_bytes(b: u64) -> String {
    const K: f64 = 1024.0;
    let bf = b as f64;
    if bf >= K * K * K {
        format!("{:.2} GiB", bf / (K * K * K))
    } else if bf >= K * K {
        format!("{:.2} MiB", bf / (K * K))
    } else if bf >= K {
        format!("{:.2} KiB", bf / K)
    } else {
        format!("{b} B")
    }
}

/// Geometric mean of a slice (ignores non-positive entries).
pub fn geomean(xs: &[f64]) -> f64 {
    let vals: Vec<f64> = xs.iter().copied().filter(|x| *x > 0.0).collect();
    if vals.is_empty() {
        return 0.0;
    }
    let s: f64 = vals.iter().map(|x| x.ln()).sum();
    (s / vals.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basic() {
        assert_eq!(ceil_div(10, 3), 4);
        assert_eq!(ceil_div(9, 3), 3);
        assert_eq!(ceil_div(0, 3), 0);
        assert_eq!(ceil_div(1, 1), 1);
    }

    #[test]
    fn perfect_squares() {
        assert!(is_perfect_square(1));
        assert!(is_perfect_square(4));
        assert!(is_perfect_square(256));
        assert!(!is_perfect_square(2));
        assert!(!is_perfect_square(12));
        assert_eq!(isqrt_exact(144), 12);
    }

    #[test]
    fn human_bytes_formats() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn geomean_basic() {
        let g = geomean(&[1.0, 4.0]);
        assert!((g - 2.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
    }
}
