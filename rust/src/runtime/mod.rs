//! PJRT runtime: load the AOT artifacts and serve them to the
//! coordinator's hot path.
//!
//! `make artifacts` (Python, build-time only) lowers every L2 op to HLO
//! text + `manifest.json`. At startup the device service parses the
//! manifest, compiles each module **once** on a PJRT CPU client
//! (`HloModuleProto::from_text_file` → `client.compile`), and
//! [`PjrtBackend`] dispatches compute by `(op, input shapes)`.
//!
//! Threading: PJRT handles are not `Send`/`Sync`, but coordinator ranks
//! are OS threads — so executables live on dedicated **device-service
//! threads** (one PJRT client each, mirroring the paper's 4-GPUs-per-
//! node), and ranks submit exec requests over channels. Shapes missing
//! from the manifest fall back to the native backend and are counted
//! ([`PjrtBackend::fallbacks`]), so benches can report the PJRT hit
//! rate honestly.
//!
//! The serving half of the runtime is [`tenants`]: a long-lived
//! multi-tenant stream service (warm models, admission control,
//! snapshot/restore) that runs on the native backend and needs no
//! AOT artifacts.

pub mod manifest;
pub mod service;
pub mod backend;
pub mod tenants;

pub use backend::PjrtBackend;
pub use manifest::{Manifest, OpEntry, TensorSpec};
pub use service::{DeviceService, HostTensor};
pub use tenants::{run_script, run_script_with_policy, EvictPolicy, TenantService, TenantSpec};

/// Default artifacts directory (override with `VIVALDI_ARTIFACTS`).
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("VIVALDI_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}

/// True when AOT artifacts are present.
pub fn artifacts_available() -> bool {
    artifacts_dir().join("manifest.json").exists()
}
