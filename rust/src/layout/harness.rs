//! The shared rank harness: everything a distributed fit repeats
//! around its actual communication schedule.
//!
//! Before this module, every `algo_*.rs` and both `approx` rank
//! functions carried identical copies of (a) the
//! `MemTracker::new`-vs-`unlimited` construction, (b) the convergence
//! loop skeleton (curves, iteration count, stop-on-stable), and (c) the
//! `RankOutput` → `FitResult` assembly in the two `fit` entry points.
//! One copy of each now lives here.

use crate::comm::CommStats;
use crate::config::MemModel;
use crate::kkmeans::{FitResult, RankOutput};
use crate::model::MemTracker;
use crate::util::timing::Stopwatch;
use crate::VivaldiError;

/// Resolve a fit's optional memory model into the effective model plus
/// this rank's tracker: enforcing when a model is given, unlimited
/// otherwise.
pub fn rank_tracker(rank: usize, mem: Option<MemModel>) -> (MemModel, MemTracker) {
    match mem {
        Some(m) => (m, MemTracker::new(rank, m.budget)),
        None => (MemModel::unlimited(), MemTracker::unlimited(rank)),
    }
}

/// What the shared convergence loop produced.
#[derive(Debug, Clone)]
pub struct LoopOutcome {
    pub iterations: usize,
    pub converged: bool,
    pub objective_curve: Vec<f64>,
    pub changes_curve: Vec<u64>,
}

/// Run the shared clustering-loop skeleton: `step(iter)` performs one
/// full distributed iteration and returns (global assignment changes,
/// global objective). Stops early on zero changes when
/// `converge_on_stable` — identical semantics on every algorithm, so
/// distributed runs of *any* layout agree on iteration counts.
pub fn drive_loop(
    max_iters: usize,
    converge_on_stable: bool,
    step: impl FnMut(usize) -> (u64, f64),
) -> LoopOutcome {
    drive_loop_tol(max_iters, converge_on_stable, 0.0, step)
}

/// [`drive_loop`] with an **objective-based stopping rule**: with
/// `tol > 0`, the loop additionally stops once the relative objective
/// drop between consecutive iterations falls below `tol` — i.e.
/// `(prev − obj) < tol·|prev|` — counting as convergence. A rising or
/// flat objective trips the rule too (the drop is ≤ 0 < tol·|prev| for
/// any positive prev magnitude). `tol = 0` disables the rule entirely:
/// the fixed-iteration schedule runs bit-identically to [`drive_loop`]
/// — the rule is gated on `tol > 0.0` before any comparison, so no
/// arithmetic path changes (pinned by the harness and stream tests).
pub fn drive_loop_tol(
    max_iters: usize,
    converge_on_stable: bool,
    tol: f64,
    mut step: impl FnMut(usize) -> (u64, f64),
) -> LoopOutcome {
    let mut objective_curve = Vec::new();
    let mut changes_curve = Vec::new();
    let mut iterations = 0;
    let mut converged = false;
    let mut prev_obj: Option<f64> = None;
    for it in 0..max_iters {
        let (changes, obj) = step(it);
        objective_curve.push(obj);
        changes_curve.push(changes);
        iterations += 1;
        if changes == 0 && converge_on_stable {
            converged = true;
            break;
        }
        if tol > 0.0 {
            if let Some(prev) = prev_obj {
                if prev - obj < tol * prev.abs() {
                    converged = true;
                    break;
                }
            }
            prev_obj = Some(obj);
        }
    }
    LoopOutcome { iterations, converged, objective_curve, changes_curve }
}

/// Package a rank's final state into the [`RankOutput`] every algorithm
/// returns.
pub fn finish_rank(
    assign: Vec<u32>,
    stopwatch: Stopwatch,
    outcome: LoopOutcome,
    tracker: &MemTracker,
) -> RankOutput {
    RankOutput {
        assign,
        stopwatch,
        iterations: outcome.iterations,
        converged: outcome.converged,
        objective_curve: outcome.objective_curve,
        changes_curve: outcome.changes_curve,
        peak_mem: tracker.peak(),
    }
}

/// Assemble per-rank outcomes into a [`FitResult`], propagating a
/// collective failure (e.g. OOM — every rank reports it). Relies on the
/// canonical-reassembly property: ranks in order own contiguous slices
/// of `0..n`, so a flat concat rebuilds the global assignment vector.
pub fn assemble_fit(
    n: usize,
    p: usize,
    rank_results: Vec<Result<RankOutput, VivaldiError>>,
    comm_stats: Vec<CommStats>,
) -> Result<FitResult, VivaldiError> {
    let mut outs = Vec::with_capacity(p);
    for r in rank_results {
        outs.push(r?);
    }
    let assignments: Vec<u32> = outs.iter().flat_map(|o| o.assign.iter().copied()).collect();
    debug_assert_eq!(assignments.len(), n);
    let first = &outs[0];
    Ok(FitResult {
        iterations: first.iterations,
        converged: first.converged,
        objective_curve: first.objective_curve.clone(),
        changes_curve: first.changes_curve.clone(),
        peak_mem: outs.iter().map(|o| o.peak_mem).max().unwrap_or(0),
        rank_peaks: outs.iter().map(|o| o.peak_mem).collect(),
        timings: outs.iter().map(|o| o.stopwatch.clone()).collect(),
        comm_stats,
        assignments,
        ranks: p,
    })
}

/// Accumulates per-batch [`FitResult`]s into stream-level aggregates —
/// the scaffolding a mini-batch driver ([`crate::approx::stream`])
/// repeats around its per-batch launches, kept here next to the
/// per-batch pieces ([`rank_tracker`] / [`drive_loop`] /
/// [`assemble_fit`]) it composes with.
#[derive(Debug, Clone)]
pub struct StreamAccumulator {
    /// Assignments of every streamed point in arrival order.
    pub assignments: Vec<u32>,
    /// Total iterations across batches.
    pub iterations: usize,
    pub batch_iterations: Vec<usize>,
    /// Points contributed by each batch in arrival order.
    pub batch_points: Vec<usize>,
    /// Final objective of each batch.
    pub objective_curve: Vec<f64>,
    /// True while every absorbed batch converged.
    pub converged: bool,
    /// Max peak tracked memory over ranks and batches.
    pub peak_mem: u64,
    /// Per-rank peak tracked memory, max over batches — the streaming
    /// counterpart of [`FitResult::rank_peaks`], which is what lets the
    /// test wall pin the off-diagonal m·d/√P landmark footprint.
    pub rank_peaks: Vec<u64>,
    /// Per-rank communication ledgers summed across batches.
    pub comm_stats: Vec<CommStats>,
    /// Per-rank phase timings summed across batches.
    pub timings: Vec<Stopwatch>,
    ranks: usize,
}

impl StreamAccumulator {
    pub fn new(p: usize) -> Self {
        StreamAccumulator {
            assignments: Vec::new(),
            iterations: 0,
            batch_iterations: Vec::new(),
            batch_points: Vec::new(),
            objective_curve: Vec::new(),
            converged: true,
            peak_mem: 0,
            rank_peaks: vec![0; p],
            comm_stats: vec![CommStats::new(); p],
            timings: vec![Stopwatch::new(); p],
            ranks: p,
        }
    }

    /// Fold one batch's [`FitResult`] into the stream aggregates.
    pub fn absorb(&mut self, batch: FitResult) {
        debug_assert_eq!(batch.ranks, self.ranks, "batches must run on the same rank count");
        self.iterations += batch.iterations;
        self.batch_iterations.push(batch.iterations);
        self.batch_points.push(batch.assignments.len());
        self.objective_curve.push(batch.objective_curve.last().copied().unwrap_or(0.0));
        self.converged &= batch.converged;
        self.peak_mem = self.peak_mem.max(batch.peak_mem);
        for (acc, &p) in self.rank_peaks.iter_mut().zip(&batch.rank_peaks) {
            *acc = (*acc).max(p);
        }
        for (acc, s) in self.comm_stats.iter_mut().zip(&batch.comm_stats) {
            acc.absorb(s);
        }
        for (acc, t) in self.timings.iter_mut().zip(&batch.timings) {
            acc.merge(t);
        }
        self.assignments.extend(batch.assignments);
    }

    /// Batches absorbed so far.
    pub fn batches(&self) -> usize {
        self.batch_iterations.len()
    }

    /// Re-target the accumulator at a new rank count after a
    /// checkpointed recovery re-lays-out the world (p → p′). The
    /// per-rank vectors keep at least their original length — `absorb`
    /// zips, so batches run on fewer ranks simply leave the tail
    /// entries untouched and history accumulated on the old world is
    /// preserved — and only grow if the world somehow widens.
    pub fn rebase_ranks(&mut self, p: usize) {
        self.ranks = p;
        if self.rank_peaks.len() < p {
            self.rank_peaks.resize(p, 0);
        }
        if self.comm_stats.len() < p {
            self.comm_stats.resize(p, CommStats::new());
        }
        if self.timings.len() < p {
            self.timings.resize(p, Stopwatch::new());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracker_resolution() {
        let (_, unlimited) = rank_tracker(0, None);
        assert!(unlimited.try_alloc(u64::MAX / 2, "huge"));
        let model = MemModel { budget: 100, repl_factor: 1.0, redist_factor: 0.0 };
        let (m, limited) = rank_tracker(3, Some(model));
        assert_eq!(m.budget, 100);
        assert!(limited.try_alloc(100, "fits"));
        assert!(!limited.try_alloc(1, "over"));
        assert_eq!(limited.rank(), 3);
    }

    #[test]
    fn loop_stops_on_stable() {
        let mut seq = vec![(3u64, 9.0), (1, 5.0), (0, 5.0), (7, 1.0)].into_iter();
        let out = drive_loop(10, true, |_| seq.next().unwrap());
        assert_eq!(out.iterations, 3);
        assert!(out.converged);
        assert_eq!(out.changes_curve, vec![3, 1, 0]);
        assert_eq!(out.objective_curve, vec![9.0, 5.0, 5.0]);
    }

    #[test]
    fn loop_runs_out_without_convergence() {
        let out = drive_loop(4, true, |it| (1 + it as u64, 0.0));
        assert_eq!(out.iterations, 4);
        assert!(!out.converged);
        // Zero changes without converge_on_stable keeps iterating.
        let out = drive_loop(3, false, |_| (0, 0.0));
        assert_eq!(out.iterations, 3);
        assert!(!out.converged);
    }

    #[test]
    fn tol_zero_reproduces_fixed_schedule_exactly() {
        // The pinning test for the stopping rule: tol = 0 must replay
        // the fixed-iteration schedule verbatim — same iterations, same
        // curves, same convergence flag — for converging and
        // non-converging sequences alike.
        let seqs: Vec<Vec<(u64, f64)>> = vec![
            vec![(3, 9.0), (1, 5.0), (0, 5.0), (7, 1.0)],
            vec![(2, 8.0), (2, 7.9), (2, 7.89), (2, 7.889)],
            vec![(1, -4.0), (1, -4.1), (1, -4.11)],
        ];
        for seq in seqs {
            for stable in [true, false] {
                let mut a = seq.clone().into_iter();
                let mut b = seq.clone().into_iter();
                let base = drive_loop(seq.len(), stable, |_| a.next().unwrap());
                let tol0 = drive_loop_tol(seq.len(), stable, 0.0, |_| b.next().unwrap());
                assert_eq!(tol0.iterations, base.iterations);
                assert_eq!(tol0.converged, base.converged);
                assert_eq!(tol0.objective_curve, base.objective_curve);
                assert_eq!(tol0.changes_curve, base.changes_curve);
            }
        }
    }

    #[test]
    fn tol_stops_on_small_relative_drop() {
        // 8.0 → 7.9 is a 1.25% drop; tol = 5% stops after seeing it.
        let mut seq = vec![(2u64, 8.0), (2, 7.9), (2, 7.0), (2, 1.0)].into_iter();
        let out = drive_loop_tol(10, true, 0.05, |_| seq.next().unwrap());
        assert_eq!(out.iterations, 2);
        assert!(out.converged, "a sub-tol drop counts as convergence");
        assert_eq!(out.objective_curve, vec![8.0, 7.9]);
        // A big drop keeps the loop alive: 8.0 → 4.0 is 50%.
        let mut seq = vec![(2u64, 8.0), (2, 4.0), (2, 2.0), (2, 1.9)].into_iter();
        let out = drive_loop_tol(4, true, 0.05, |_| seq.next().unwrap());
        assert_eq!(out.iterations, 4, "halving drops never trip a 5% tol");
        // A rising objective trips the rule immediately.
        let mut seq = vec![(2u64, 5.0), (2, 6.0), (2, 1.0)].into_iter();
        let out = drive_loop_tol(10, true, 0.01, |_| seq.next().unwrap());
        assert_eq!(out.iterations, 2);
        assert!(out.converged);
    }

    #[test]
    fn stream_accumulator_folds_batches() {
        let mk = |assign: Vec<u32>, iters: usize, converged: bool, peak: u64, obj: f64| FitResult {
            assignments: assign,
            iterations: iters,
            converged,
            objective_curve: vec![obj + 1.0, obj],
            changes_curve: vec![1, 0],
            comm_stats: vec![CommStats::new(), CommStats::new()],
            timings: vec![Stopwatch::new(), Stopwatch::new()],
            peak_mem: peak,
            rank_peaks: vec![peak, peak / 2],
            ranks: 2,
        };
        let mut acc = StreamAccumulator::new(2);
        assert_eq!(acc.batches(), 0);
        acc.absorb(mk(vec![0, 1, 0], 3, true, 100, 5.0));
        acc.absorb(mk(vec![1, 1], 2, false, 40, 3.0));
        assert_eq!(acc.batches(), 2);
        assert_eq!(acc.assignments, vec![0, 1, 0, 1, 1]);
        assert_eq!(acc.iterations, 5);
        assert_eq!(acc.batch_iterations, vec![3, 2]);
        assert_eq!(acc.batch_points, vec![3, 2]);
        assert_eq!(acc.objective_curve, vec![5.0, 3.0]);
        assert!(!acc.converged, "one unconverged batch taints the stream");
        assert_eq!(acc.peak_mem, 100);
        assert_eq!(acc.rank_peaks, vec![100, 50], "per-rank peaks max across batches");
        assert_eq!(acc.comm_stats.len(), 2);
    }

    #[test]
    fn assemble_propagates_errors() {
        let err = VivaldiError::OutOfMemory { rank: 1, requested: 8, budget: 4, what: "t".into() };
        let results = vec![Err::<RankOutput, _>(err.clone()), Err(err.clone())];
        let got = assemble_fit(0, 2, results, vec![CommStats::new(), CommStats::new()]);
        assert_eq!(got.unwrap_err(), err);
    }
}
