//! The single-device sliding-window baseline (paper §VI.D) vs the
//! distributed 1.5D algorithm on the same dataset — a small-scale
//! rendition of Fig. 6's story: recomputing K blocks on the fly is
//! orders of magnitude more compute per iteration, and the gap grows
//! with the feature count d.
//!
//! Run: `cargo run --release --example sliding_window_demo`

use vivaldi::backend::NativeBackend;
use vivaldi::data::datasets::PaperDataset;
use vivaldi::kkmeans::{self, Algo, FitConfig};
use vivaldi::metrics::Table;
use vivaldi::sliding_window::{sliding_window_fit, SwConfig};

fn main() {
    let n = 2048;
    let iters = 5;
    let be = NativeBackend::new();
    let mut table = Table::new(
        "Sliding window vs distributed 1.5D (16 ranks), wall seconds",
        &["dataset", "d", "t_sw", "blocks recomputed", "t_1.5D", "ratio"],
    );

    for ds in [PaperDataset::HiggsLike, PaperDataset::Mnist8mLike] {
        let d_cap = match ds {
            PaperDataset::Mnist8mLike => Some(256),
            _ => None,
        };
        let data = ds.generate(n, d_cap, 3);

        let sw_cfg = SwConfig {
            k: 16,
            max_iters: iters,
            block: 256,
            converge_on_stable: false,
            ..Default::default()
        };
        let t0 = std::time::Instant::now();
        let sw = sliding_window_fit(&data.points, &sw_cfg, &be);
        let t_sw = t0.elapsed().as_secs_f64();

        let cfg = FitConfig { k: 16, max_iters: iters, converge_on_stable: false, ..Default::default() };
        let t0 = std::time::Instant::now();
        let kk = kkmeans::fit(Algo::OneFiveD, 16, &data.points, &cfg).expect("fit");
        let t_15d = t0.elapsed().as_secs_f64();

        // Same fixed point: identical math, different schedules.
        assert_eq!(sw.assignments, kk.assignments, "baseline and 1.5D must agree");

        table.row(vec![
            ds.name().into(),
            data.d().to_string(),
            format!("{t_sw:.3}"),
            sw.blocks_recomputed.to_string(),
            format!("{t_15d:.3}"),
            format!("{:.1}x", t_sw / t_15d),
        ]);
    }
    table.print();
    println!("The ratio grows with d — recomputing K dominates (Fig. 6).");
}
