"""L1 Pallas kernels vs the pure-jnp oracle (ref.py).

Fixed-shape smoke tests plus hypothesis sweeps over shapes, cluster
counts and value ranges. All Pallas calls run under interpret=True (CPU
lowering of the TPU kernels).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import distance, gram, ref, spmm

RNG = np.random.default_rng(1234)


def f32(a):
    return jnp.asarray(a, dtype=jnp.float32)


def rand(*shape, scale=1.0):
    return f32(RNG.normal(size=shape) * scale)


# --- gram ---------------------------------------------------------------


@pytest.mark.parametrize("m,n,d", [(4, 4, 3), (16, 8, 5), (128, 128, 64), (96, 256, 28)])
def test_gram_poly_matches_ref(m, n, d):
    a, b = rand(m, d), rand(n, d)
    got = gram.gram_tile(a, b, kind="poly")
    want = ref.gram_poly(a, b)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("kind", ["linear", "poly", "rbf"])
def test_gram_kinds(kind):
    a, b = rand(32, 7), rand(24, 7)
    got = gram.gram_tile(a, b, kind=kind, gamma=0.5, c=2.0, degree=3.0)
    if kind == "linear":
        want = ref.gram_linear(a, b)
    elif kind == "poly":
        want = ref.gram_poly(a, b, gamma=0.5, c=2.0, degree=3.0)
    else:
        want = ref.gram_rbf(a, b, gamma=0.5)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=2e-4, atol=2e-4)


def test_gram_symmetry():
    a = rand(40, 6)
    k = np.array(gram.gram_tile(a, a, kind="poly"))
    np.testing.assert_allclose(k, k.T, rtol=1e-5, atol=1e-5)


def test_kernel_apply_poly():
    b = rand(64, 48)
    got = gram.kernel_apply(b, kind="poly", gamma=1.0, c=1.0, degree=2.0)
    want = ref.kernel_apply_poly(b)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 80),
    n=st.integers(1, 80),
    d=st.integers(1, 40),
    gamma=st.floats(0.1, 2.0),
    c=st.floats(0.0, 3.0),
)
def test_gram_poly_hypothesis(m, n, d, gamma, c):
    a, b = rand(m, d, scale=0.5), rand(n, d, scale=0.5)
    got = gram.gram_tile(a, b, kind="poly", gamma=gamma, c=c, degree=2.0)
    want = ref.gram_poly(a, b, gamma=gamma, c=c, degree=2.0)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=1e-3, atol=1e-3)


# --- spmm ---------------------------------------------------------------


@pytest.mark.parametrize("m,nr,k", [(8, 8, 2), (128, 512, 16), (64, 96, 7), (33, 50, 3)])
def test_spmm_vk_matches_ref(m, nr, k):
    kt = rand(m, nr)
    assign = jnp.asarray(RNG.integers(0, k, size=nr), dtype=jnp.int32)
    inv = f32(RNG.uniform(0.05, 1.0, size=k))
    got = spmm.spmm_vk(kt, assign, inv)
    want = ref.spmm_vk(kt, assign, inv)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("nr,m,k", [(8, 8, 2), (512, 128, 16), (96, 64, 5)])
def test_spmm_vk_t_matches_ref(nr, m, k):
    kt = rand(nr, m)
    assign = jnp.asarray(RNG.integers(0, k, size=nr), dtype=jnp.int32)
    inv = f32(RNG.uniform(0.05, 1.0, size=k))
    got = spmm.spmm_vk_t(kt, assign, inv)
    want = ref.spmm_vk_t(kt, assign, inv)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(m=st.integers(1, 64), nr=st.integers(1, 96), k=st.integers(1, 24))
def test_spmm_vk_hypothesis(m, nr, k):
    kt = rand(m, nr)
    assign = jnp.asarray(RNG.integers(0, k, size=nr), dtype=jnp.int32)
    inv = f32(RNG.uniform(0.05, 1.0, size=k))
    got = spmm.spmm_vk(kt, assign, inv)
    want = ref.spmm_vk(kt, assign, inv)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=1e-3, atol=1e-3)


def test_spmm_perfect_load_balance_semantics():
    # All points in one cluster: E column 0 = row sums · inv[0].
    kt = rand(16, 32)
    assign = jnp.zeros(32, dtype=jnp.int32)
    inv = f32([0.25, 1.0])
    e = np.array(spmm.spmm_vk(kt, assign, inv))
    np.testing.assert_allclose(e[:, 0], np.array(kt).sum(axis=1) * 0.25, rtol=1e-4)
    np.testing.assert_allclose(e[:, 1], 0.0)


# --- update -------------------------------------------------------------


@pytest.mark.parametrize("m,k", [(4, 2), (512, 16), (100, 7)])
def test_update_post_matches_ref(m, k):
    e = rand(m, k)
    c = rand(k)
    am, mv = distance.update_post(e, c)
    am2, mv2 = ref.update_post(e, c)
    np.testing.assert_array_equal(np.array(am), np.array(am2))
    np.testing.assert_allclose(np.array(mv), np.array(mv2), rtol=1e-5, atol=1e-5)


def test_update_post_tie_breaks_low():
    e = f32([[1.0, 1.0, 0.0]])
    c = f32([0.0, 0.0, 2.0])
    am, mv = distance.update_post(e, c)
    assert int(am[0]) == 0
    assert float(mv[0]) == -2.0


@pytest.mark.parametrize("m,k", [(8, 2), (512, 16), (96, 5)])
def test_update_pre_matches_ref(m, k):
    e = rand(m, k)
    assign = jnp.asarray(RNG.integers(0, k, size=m), dtype=jnp.int32)
    inv = f32(RNG.uniform(0.05, 1.0, size=k))
    got = distance.update_pre(e, assign, inv)
    want = ref.update_pre(e, assign, inv)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(m=st.integers(1, 128), k=st.integers(1, 32))
def test_update_post_hypothesis(m, k):
    e = rand(m, k)
    c = rand(k)
    am, mv = distance.update_post(e, c)
    am2, mv2 = ref.update_post(e, c)
    np.testing.assert_array_equal(np.array(am), np.array(am2))
    np.testing.assert_allclose(np.array(mv), np.array(mv2), rtol=1e-4, atol=1e-4)
