//! Streaming landmark Kernel K-means: the one-batch exactness anchor
//! (bit-identical to `approx::fit`), multi-batch quality on the
//! non-linearly-separable rings, oracle equivalence across rank counts,
//! reservoir determinism, and the batch-bounded memory guarantee the
//! subsystem exists for.

use vivaldi::approx::stream::{fit_stream, StreamConfig, StreamFitResult};
use vivaldi::approx::{self, oracle as approx_oracle, ApproxConfig, LandmarkLayout};
use vivaldi::config::MemModel;
use vivaldi::data::landmarks::LandmarkReservoir;
use vivaldi::data::stream::{MatrixSource, PointSource};
use vivaldi::data::synth;
use vivaldi::dense::DenseMatrix;
use vivaldi::kernelfn::KernelFn;
use vivaldi::quality::nmi;
use vivaldi::VivaldiError;

/// Acceptance anchor: a stream that delivers everything in one batch
/// must be **bit-identical** to the batch `approx::fit` — same
/// assignments, same iteration count — on both landmark layouts.
#[test]
fn single_batch_stream_is_bit_identical_to_batch_fit() {
    // Polynomial kernel on blobs and Gaussian on rings, so both the
    // norm-free and norm-carrying Gram paths are pinned.
    let blobs = synth::gaussian_blobs(144, 5, 4, 4.5, 301);
    let rings = synth::concentric_rings(144, 2, 302);
    let cases: [(&DenseMatrix, usize, KernelFn); 2] = [
        (&blobs.points, 4, KernelFn::paper_polynomial()),
        (&rings.points, 2, KernelFn::gaussian(2.0)),
    ];
    for (points, k, kernel) in cases {
        for layout in [LandmarkLayout::OneD, LandmarkLayout::OneFiveD] {
            for p in [1usize, 4] {
                let base = ApproxConfig {
                    k,
                    m: 36,
                    layout,
                    kernel,
                    max_iters: 40,
                    ..Default::default()
                };
                let want = approx::fit(p, points, &base).unwrap();
                let cfg = StreamConfig { base, batch: points.rows(), ..Default::default() };
                let mut src = MatrixSource::new(points);
                let got = fit_stream(p, &mut src, &cfg).unwrap();
                assert_eq!(got.batches, 1, "whole set must arrive as one batch");
                assert_eq!(
                    got.assignments,
                    want.assignments,
                    "layout={} p={p} k={k}: one-batch stream must be bit-identical",
                    layout.name()
                );
                assert_eq!(
                    got.iterations,
                    want.iterations,
                    "layout={} p={p}: iteration counts must agree",
                    layout.name()
                );
                assert_eq!(got.converged, want.converged);
            }
        }
    }
}

/// The issue's quality bar: multi-batch streaming on concentric rings
/// reaches ≥ 0.85 NMI with m = n/8 landmarks (landmarks seeded from
/// the first batch only, model carried across batches).
#[test]
fn multi_batch_rings_quality() {
    let n = 512;
    for seed in [311u64, 312] {
        let ds = synth::concentric_rings(n, 2, seed);
        let cfg = StreamConfig {
            base: ApproxConfig {
                k: 2,
                m: n / 8,
                kernel: KernelFn::gaussian(2.0),
                max_iters: 30,
                ..Default::default()
            },
            batch: 128,
            ..Default::default()
        };
        for p in [1usize, 4] {
            let mut src = MatrixSource::new(&ds.points);
            let out = fit_stream(p, &mut src, &cfg).unwrap();
            assert_eq!(out.batches, 4);
            assert_eq!(out.assignments.len(), n);
            let score = nmi(&out.assignments, &ds.labels, 2);
            assert!(score >= 0.85, "seed={seed} p={p} nmi={score}");
        }
    }
}

/// Oracle equivalence at p ∈ {1, 4}: the one-batch stream must reach
/// the independent single-rank landmark oracle's fixed point (same
/// one-boundary-point tolerance as the batch path's oracle wall — the
/// oracle sums in f64, the distributed side in f32).
#[test]
fn stream_matches_oracle_at_p_1_4() {
    let kernel = KernelFn::paper_polynomial();
    for seed in [321u64, 322] {
        let ds = synth::gaussian_blobs(144, 5, 4, 4.5, seed);
        for p in [1usize, 4] {
            let base = ApproxConfig { k: 4, m: 48, kernel, max_iters: 40, ..Default::default() };
            let lidx = approx::landmark_indices(&ds.points, &base, p);
            let want = approx_oracle::reference_fit(&ds.points, &lidx, 4, &kernel, 40);
            assert!(want.converged, "oracle must converge (seed={seed} p={p})");
            let cfg = StreamConfig { base, batch: 144, ..Default::default() };
            let mut src = MatrixSource::new(&ds.points);
            let out = fit_stream(p, &mut src, &cfg).unwrap();
            let diffs =
                out.assignments.iter().zip(&want.assignments).filter(|(a, b)| a != b).count();
            assert!(
                diffs <= 1,
                "seed={seed} p={p}: {diffs}/{} points disagree with the oracle",
                out.assignments.len()
            );
            let score = nmi(&out.assignments, &want.assignments, 4);
            assert!(score >= 0.99, "seed={seed} p={p} nmi-vs-oracle={score}");
        }
    }
}

/// Landmark reservoir determinism under a fixed seed: the reservoir
/// itself, and a full streaming fit that selects its landmarks through
/// reservoir + k-means++ refresh, both replay identically.
#[test]
fn reservoir_determinism_under_fixed_seed() {
    let ds = synth::gaussian_blobs(384, 3, 3, 4.5, 331);
    // The raw reservoir replays bit-identically and respects capacity.
    let feed = |seed: u64| {
        let mut r = LandmarkReservoir::new(48, 3, seed);
        let mut src = MatrixSource::new(&ds.points);
        while let Some(b) = src.next_batch(96).expect("in-memory source cannot fail") {
            r.observe(&b);
        }
        r
    };
    let r1 = feed(7);
    let r2 = feed(7);
    assert_eq!(r1.snapshot(), r2.snapshot());
    assert_eq!(r1.len(), 48);
    assert_eq!(r1.seen(), 384);
    assert_eq!(r1.refresh_kmeanspp(24, 9), r2.refresh_kmeanspp(24, 9));
    assert_ne!(feed(8).snapshot(), r1.snapshot(), "a different seed keeps a different sample");

    // End-to-end: reservoir-seeded streaming fits replay identically
    // and still cluster the blobs.
    let cfg = StreamConfig {
        base: ApproxConfig { k: 3, m: 24, max_iters: 25, ..Default::default() },
        batch: 96,
        reservoir: 48,
        refresh_every: 2,
        ..Default::default()
    };
    let run = || {
        let mut src = MatrixSource::new(&ds.points);
        fit_stream(4, &mut src, &cfg).unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.assignments, b.assignments);
    assert_eq!(a.batch_iterations, b.batch_iterations);
    assert_eq!(a.landmark_refreshes, b.landmark_refreshes);
    assert!(a.landmark_refreshes >= 1, "the refresh path must actually run");
    let score = nmi(&a.assignments, &ds.labels, 3);
    assert!(score >= 0.85, "nmi={score}");
}

/// The acceptance-criteria memory guarantee, asserted through the
/// MemTracker: peak tracked memory of a streaming fit depends on the
/// batch size, **not** on the stream length — and sits strictly below
/// the batch path's n-proportional footprint.
#[test]
fn stream_peak_memory_is_batch_bound_not_n_bound() {
    let mem = Some(MemModel { budget: 2 << 20, repl_factor: 1.0, redist_factor: 0.0 });
    let big = synth::concentric_rings(1024, 2, 341);
    let small = big.points.row_block(0, 256);
    let base = ApproxConfig {
        k: 2,
        m: 32,
        kernel: KernelFn::gaussian(2.0),
        max_iters: 10,
        mem,
        ..Default::default()
    };
    let run_stream = |points: &DenseMatrix| -> StreamFitResult {
        let cfg = StreamConfig { base: base.clone(), batch: 128, ..Default::default() };
        let mut src = MatrixSource::new(points);
        fit_stream(4, &mut src, &cfg).unwrap()
    };
    let two_batches = run_stream(&small);
    let eight_batches = run_stream(&big.points);
    assert_eq!(two_batches.batches, 2);
    assert_eq!(eight_batches.batches, 8);
    assert!(two_batches.peak_mem > 0, "the tracker must actually charge the stream state");
    assert_eq!(
        two_batches.peak_mem, eight_batches.peak_mem,
        "peak tracked memory must be independent of the stream length"
    );
    // The batch path's C block scales with n; at n = 1024 it dominates
    // the stream's batch-sized footprint.
    let batch_fit = approx::fit(4, &big.points, &base).unwrap();
    assert!(
        eight_batches.peak_mem < batch_fit.peak_mem,
        "stream peak {} must undercut the batch path's n-proportional peak {}",
        eight_batches.peak_mem,
        batch_fit.peak_mem
    );
}

/// The workload-opening claim end-to-end: under a budget where even the
/// *batch landmark* path OOMs (its C block scales with n), the
/// streaming path completes and still separates the rings.
#[test]
fn stream_runs_where_batch_landmark_ooms() {
    let n = 2048;
    let m = 128;
    let p = 4;
    let ds = synth::concentric_rings(n, 2, 351);
    let mem = MemModel { budget: 150 << 10, repl_factor: 1.0, redist_factor: 0.0 };
    let base = ApproxConfig {
        k: 2,
        m,
        kernel: KernelFn::gaussian(2.0),
        max_iters: 20,
        mem: Some(mem),
        ..Default::default()
    };

    // Batch landmark path: n/p × m C block + W busts the budget.
    assert!(matches!(
        approx::fit(p, &ds.points, &base),
        Err(VivaldiError::OutOfMemory { .. })
    ));

    // Streaming at B = 256: the C block shrinks to B/p × m and fits.
    let cfg = StreamConfig { base, batch: 256, ..Default::default() };
    let mut src = MatrixSource::new(&ds.points);
    let out = fit_stream(p, &mut src, &cfg).unwrap();
    assert_eq!(out.batches, 8);
    assert!(out.peak_mem <= mem.budget);
    let score = nmi(&out.assignments, &ds.labels, 2);
    assert!(score >= 0.85, "nmi={score}");
}

/// Decay keeps the model adaptive without breaking stationary-stream
/// quality: γ < 1 on a stationary rings stream must still clear the
/// quality bar, and the decayed run replays deterministically.
#[test]
fn decayed_accumulation_on_stationary_stream() {
    let n = 512;
    let ds = synth::concentric_rings(n, 2, 361);
    let cfg = StreamConfig {
        base: ApproxConfig {
            k: 2,
            m: n / 8,
            kernel: KernelFn::gaussian(2.0),
            max_iters: 30,
            ..Default::default()
        },
        batch: 128,
        decay: 0.7,
        ..Default::default()
    };
    let run = || {
        let mut src = MatrixSource::new(&ds.points);
        fit_stream(4, &mut src, &cfg).unwrap()
    };
    let a = run();
    assert_eq!(a.assignments, run().assignments);
    let score = nmi(&a.assignments, &ds.labels, 2);
    assert!(score >= 0.85, "nmi={score}");
}

/// The streaming 1.5D landmark block gather bounds every off-diagonal
/// rank's tracked peak at the batch C tile + its m·d/√P landmark block
/// — strictly below the old full-L charge — and the peak stays
/// batch-bounded: a 2× longer stream has the identical per-rank peaks.
#[test]
fn stream_offdiag_peak_is_landmark_block_scale() {
    let m = 64;
    let d = 16;
    let batch = 128;
    let p = 4;
    let q = 2;
    let mut rng = vivaldi::util::rng::Rng::new(381);
    let big = DenseMatrix::random(512, d, &mut rng);
    let small = big.row_block(0, 256);
    let mem = Some(MemModel { budget: 2 << 20, repl_factor: 1.0, redist_factor: 0.0 });
    let run = |points: &DenseMatrix| {
        let cfg = StreamConfig {
            base: ApproxConfig {
                k: 2,
                m,
                layout: LandmarkLayout::OneFiveD,
                kernel: KernelFn::linear(),
                max_iters: 5,
                mem,
                ..Default::default()
            },
            batch,
            ..Default::default()
        };
        let mut src = MatrixSource::new(points);
        fit_stream(p, &mut src, &cfg).unwrap()
    };
    let two = run(&small);
    let four = run(&big);
    assert_eq!(two.batches, 2);
    assert_eq!(four.batches, 4);
    assert_eq!(two.rank_peaks, four.rank_peaks, "per-rank peaks are batch-bounded");

    // Off-diagonal charge: C tile (batch/q × m/q) + the m/q × d
    // landmark block — and nothing else. The old path charged the full
    // m×d L on every rank.
    let c_tile = (batch / q * (m / q) * 4) as u64;
    let block_bound = c_tile + (m / q * d * 4) as u64;
    let full_l_bound = c_tile + (m * d * 4) as u64;
    for r in 0..p {
        let (i, j) = (r % q, r / q);
        if i == j {
            continue;
        }
        let peak = four.rank_peaks[r];
        assert!(
            peak <= block_bound,
            "off-diagonal rank {r}: peak {peak} exceeds C tile + m·d/√P block {block_bound}"
        );
        assert!(
            peak < full_l_bound,
            "off-diagonal rank {r}: peak {peak} must undercut the full-L charge {full_l_bound}"
        );
    }
}

/// An undersized tail on the 1.5D block-cyclic stream is classified
/// driver-side through the panel-set solve (the driver holds no host
/// W after the distributed stream-init) — every point labeled, and
/// bit-identical to the replicated-W stream on the same data.
#[test]
fn fifteen_d_stream_tail_classified_via_panel_solve() {
    let ds = synth::gaussian_blobs(258, 3, 2, 4.5, 391);
    let mk = |wfact| StreamConfig {
        base: ApproxConfig {
            k: 2,
            m: 24,
            layout: LandmarkLayout::OneFiveD,
            w_fact: wfact,
            max_iters: 20,
            ..Default::default()
        },
        batch: 64,
        ..Default::default()
    };
    let run = |wfact| {
        let mut src = MatrixSource::new(&ds.points);
        fit_stream(4, &mut src, &mk(wfact)).unwrap()
    };
    let bc = run(vivaldi::layout::WFactorization::BlockCyclic);
    assert_eq!(bc.n_total, 258);
    assert_eq!(bc.assignments.len(), 258);
    assert_eq!(bc.batches, 5, "4 driven batches + the 2-point classified tail");
    assert_eq!(*bc.batch_iterations.last().unwrap(), 0, "tail runs no inner loop");
    // The panel-set host solve is bit-identical to the replicated one.
    let repl = run(vivaldi::layout::WFactorization::Replicated);
    assert_eq!(bc.assignments, repl.assignments);
    let score = nmi(&bc.assignments, &ds.labels, 2);
    assert!(score > 0.85, "nmi = {score}");
}

/// The `tol` objective-based stopping rule: tol = 0 (the default) must
/// reproduce the fixed-iteration schedule **exactly** — the other half
/// of the `--inner-iters` knob only engages when asked.
#[test]
fn tol_zero_is_bit_identical_to_fixed_schedule() {
    let n = 512;
    let ds = synth::concentric_rings(n, 2, 401);
    let mk = |tol: f64| StreamConfig {
        base: ApproxConfig {
            k: 2,
            m: n / 8,
            kernel: KernelFn::gaussian(2.0),
            max_iters: 12,
            converge_on_stable: false,
            ..Default::default()
        },
        batch: 128,
        tol,
        ..Default::default()
    };
    // StreamConfig::default() leaves tol at 0.0 — the rule is opt-in.
    assert_eq!(StreamConfig::default().tol, 0.0);
    for p in [1usize, 4] {
        let mut s1 = MatrixSource::new(&ds.points);
        let fixed = fit_stream(p, &mut s1, &mk(0.0)).unwrap();
        // With converge_on_stable off and tol 0, every batch runs the
        // full budget — the fixed schedule the tol=0 contract pins —
        // even though the objective visibly plateaus within it.
        assert!(
            fixed.batch_iterations.iter().all(|&it| it == 12),
            "p={p}: tol=0 must run the fixed schedule: {:?}",
            fixed.batch_iterations
        );
        // And a replay is bit-identical (the rule adds no hidden state).
        let mut s2 = MatrixSource::new(&ds.points);
        let again = fit_stream(p, &mut s2, &mk(0.0)).unwrap();
        assert_eq!(fixed.assignments, again.assignments, "p={p}");
        assert_eq!(fixed.objective_curve, again.objective_curve, "p={p}");
    }
}

/// tol > 0 stops converged batches early (fewer inner iterations, same
/// clustering quality), and an invalid tol is rejected up front.
#[test]
fn tol_stops_converged_batches_early() {
    let n = 512;
    let ds = synth::concentric_rings(n, 2, 402);
    let mk = |tol: f64| StreamConfig {
        base: ApproxConfig {
            k: 2,
            m: n / 8,
            kernel: KernelFn::gaussian(2.0),
            max_iters: 12,
            converge_on_stable: false,
            ..Default::default()
        },
        batch: 128,
        tol,
        ..Default::default()
    };
    let mut s1 = MatrixSource::new(&ds.points);
    let fixed = fit_stream(4, &mut s1, &mk(0.0)).unwrap();
    let mut s2 = MatrixSource::new(&ds.points);
    let tolled = fit_stream(4, &mut s2, &mk(1e-3)).unwrap();
    assert!(
        tolled.iterations < fixed.iterations,
        "tol must shave iterations: {} !< {}",
        tolled.iterations,
        fixed.iterations
    );
    assert!(
        tolled.batch_iterations.iter().zip(&fixed.batch_iterations).all(|(a, b)| a <= b),
        "tol never adds iterations: {:?} vs {:?}",
        tolled.batch_iterations,
        fixed.batch_iterations
    );
    let score = nmi(&tolled.assignments, &ds.labels, 2);
    assert!(score >= 0.85, "early stopping must not cost quality: nmi={score}");

    // Invalid tol values are config errors, not silent behavior.
    for bad in [-0.5, f64::NAN, f64::INFINITY] {
        let mut src = MatrixSource::new(&ds.points);
        assert!(
            matches!(
                fit_stream(4, &mut src, &mk(bad)),
                Err(VivaldiError::InvalidConfig(_))
            ),
            "tol={bad} must be rejected"
        );
    }
}

/// The 1.5D landmark layout streams too: multi-batch quality holds and
/// the layouts agree with each other on the same stream.
#[test]
fn fifteen_d_layout_streams() {
    let n = 512;
    let ds = synth::concentric_rings(n, 2, 371);
    let mk = |layout| StreamConfig {
        base: ApproxConfig {
            k: 2,
            m: n / 8,
            layout,
            kernel: KernelFn::gaussian(2.0),
            max_iters: 30,
            ..Default::default()
        },
        batch: 128,
        ..Default::default()
    };
    for p in [1usize, 4] {
        let mut s1 = MatrixSource::new(&ds.points);
        let a = fit_stream(p, &mut s1, &mk(LandmarkLayout::OneD)).unwrap();
        let mut s2 = MatrixSource::new(&ds.points);
        let b = fit_stream(p, &mut s2, &mk(LandmarkLayout::OneFiveD)).unwrap();
        let score_a = nmi(&a.assignments, &ds.labels, 2);
        let score_b = nmi(&b.assignments, &ds.labels, 2);
        assert!(score_a >= 0.85, "p={p} 1D nmi={score_a}");
        assert!(score_b >= 0.85, "p={p} 1.5D nmi={score_b}");
        let agree = nmi(&a.assignments, &b.assignments, 2);
        assert!(agree >= 0.95, "p={p}: layouts must reach the same clustering, nmi={agree}");
    }
}
