"""L1 Pallas kernel: the structured SpMM Eᵀ = V·K.

GPU→TPU adaptation (DESIGN.md §8): the paper uses cuSPARSE CSC·dense
SpMM. V has exactly one nonzero per column, so on TPU the segment-sum
becomes a **one-hot matmul on the MXU**: materialize the (block × k)
one-hot of the assignment slice in VMEM and contract it against the K
block. k ≤ 64 keeps the one-hot tiny; the grid walks K in blocks so
every K element is read from HBM exactly once — the memory-level
analogue of the paper's communication avoidance.

Two orientations match the Rust coordinator's layouts:
  * ``spmm_vk``   — k_tile (m, nr), output E (m, k)   (1D block rows)
  * ``spmm_vk_t`` — k_tile (nr, m), output Eᵀ (k, m)  (2D tiles)
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_M = 128
BLOCK_R = 512


def _block(n, bound):
    b = min(n, bound)
    while n % b != 0:
        b -= 1
    return b


def _vk_kernel(k_ref, onehot_ref, inv_ref, o_ref, *, nsteps):
    """Accumulate E block: o (bm, k) += K(bm, br) @ onehot(br, k)."""
    r = pl.program_id(1)

    @pl.when(r == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        k_ref[...], onehot_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(r == nsteps - 1)
    def _scale():
        o_ref[...] = o_ref[...] * inv_ref[...][None, :]


@jax.jit
def spmm_vk(k_tile, assign, inv_sizes):
    """E (m,k) from k_tile (m,nr) + assignment of the nr summed points."""
    m, nr = k_tile.shape
    k = inv_sizes.shape[0]
    bm = _block(m, BLOCK_M)
    br = _block(nr, BLOCK_R)
    nsteps = nr // br
    # One-hot built once at f32 (the MXU contraction operand).
    onehot = (assign[:, None] == jnp.arange(k, dtype=assign.dtype)[None, :]).astype(
        jnp.float32
    )
    return pl.pallas_call(
        functools.partial(_vk_kernel, nsteps=nsteps),
        grid=(m // bm, nsteps),
        in_specs=[
            pl.BlockSpec((bm, br), lambda i, r: (i, r)),
            pl.BlockSpec((br, k), lambda i, r: (r, 0)),
            pl.BlockSpec((k,), lambda i, r: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, k), lambda i, r: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, k), jnp.float32),
        interpret=True,
    )(k_tile, onehot, inv_sizes)


def _vkt_kernel(onehot_ref, k_ref, inv_ref, o_ref, *, nsteps):
    """Accumulate Eᵀ block: o (k, bm) += onehotᵀ(k, br) @ K(br, bm)."""
    r = pl.program_id(1)

    @pl.when(r == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        onehot_ref[...].T, k_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(r == nsteps - 1)
    def _scale():
        o_ref[...] = o_ref[...] * inv_ref[...][:, None]


@jax.jit
def spmm_vk_t(k_tile, assign, inv_sizes):
    """Eᵀ (k,m) from k_tile (nr,m) in natural 2D orientation."""
    nr, m = k_tile.shape
    k = inv_sizes.shape[0]
    bm = _block(m, BLOCK_M)
    br = _block(nr, BLOCK_R)
    nsteps = nr // br
    onehot = (assign[:, None] == jnp.arange(k, dtype=assign.dtype)[None, :]).astype(
        jnp.float32
    )
    return pl.pallas_call(
        functools.partial(_vkt_kernel, nsteps=nsteps),
        grid=(m // bm, nsteps),
        in_specs=[
            pl.BlockSpec((br, k), lambda i, r: (r, 0)),
            pl.BlockSpec((br, bm), lambda i, r: (r, i)),
            pl.BlockSpec((k,), lambda i, r: (0,)),
        ],
        out_specs=pl.BlockSpec((k, bm), lambda i, r: (0, i)),
        out_shape=jax.ShapeDtypeStruct((k, m), jnp.float32),
        interpret=True,
    )(onehot, k_tile, inv_sizes)
