//! Table I: exact counted communication volume per algorithm vs the
//! paper's analytic α-β expressions.
mod common;

fn main() {
    let scale = common::bench_scale();
    let machine = vivaldi::model::MachineModel::perlmutter();
    common::emit(vivaldi::bench::comm_table(&scale, &machine));
}
