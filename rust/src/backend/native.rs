//! Pure-Rust backend: blocked multithreaded GEMM + structured sparse
//! kernels. Works at every shape; the reference the PJRT backend falls
//! back to and is validated against.

use super::ComputeBackend;
use crate::dense::{matrix::DenseMatrix, ops};
use crate::kernelfn::KernelFn;
use crate::sparse;

/// The native (pure Rust) compute backend.
#[derive(Debug, Default, Clone)]
pub struct NativeBackend;

impl NativeBackend {
    pub fn new() -> Self {
        NativeBackend
    }
}

impl ComputeBackend for NativeBackend {
    fn gram_tile(
        &self,
        a: &DenseMatrix,
        b: &DenseMatrix,
        kernel: &KernelFn,
        row_norms: &[f32],
        col_norms: &[f32],
    ) -> DenseMatrix {
        let mut tile = ops::matmul_nt(a, b);
        kernel.apply_tile(&mut tile, row_norms, col_norms);
        tile
    }

    fn matmul_nn_acc(&self, a: &DenseMatrix, b: &DenseMatrix, c: &mut DenseMatrix) {
        ops::matmul_nn_acc(a, b, c);
    }

    fn kernel_apply(
        &self,
        b: &mut DenseMatrix,
        kernel: &KernelFn,
        row_norms: &[f32],
        col_norms: &[f32],
    ) {
        kernel.apply_tile(b, row_norms, col_norms);
    }

    fn spmm_vk(
        &self,
        k_tile: &DenseMatrix,
        assign_r: &[u32],
        k: usize,
        inv_sizes: &[f32],
    ) -> DenseMatrix {
        sparse::ops::spmm_vk(k_tile, assign_r, k, inv_sizes)
    }

    fn spmm_vk_t(
        &self,
        k_tile: &DenseMatrix,
        assign_r: &[u32],
        k: usize,
        inv_sizes: &[f32],
    ) -> DenseMatrix {
        sparse::ops::spmm_vk_t(k_tile, assign_r, k, inv_sizes)
    }

    fn mask_z(&self, e_local: &DenseMatrix, assign: &[u32]) -> Vec<f32> {
        assert_eq!(e_local.rows(), assign.len());
        assign
            .iter()
            .enumerate()
            .map(|(j, &a)| e_local.get(j, a as usize))
            .collect()
    }

    fn spmv_vz(&self, assign: &[u32], z: &[f32], k: usize, inv_sizes: &[f32]) -> Vec<f32> {
        sparse::ops::spmv_vz(assign, z, k, inv_sizes)
    }

    fn distances_argmin(&self, e_local: &DenseMatrix, c: &[f32]) -> (Vec<u32>, Vec<f32>) {
        let k = e_local.cols();
        assert_eq!(c.len(), k);
        let m = e_local.rows();
        let mut arg = vec![0u32; m];
        let mut val = vec![0.0f32; m];
        for j in 0..m {
            let row = e_local.row(j);
            let mut best = 0usize;
            let mut best_d = -2.0 * row[0] + c[0];
            for a in 1..k {
                let d = -2.0 * row[a] + c[a];
                // Strict < : ties break to the lower cluster index.
                if d < best_d {
                    best_d = d;
                    best = a;
                }
            }
            arg[j] = best as u32;
            val[j] = best_d;
        }
        (arg, val)
    }

    fn name(&self) -> &str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn gram_tile_fuses_kernel() {
        let mut rng = Rng::new(2);
        let a = DenseMatrix::random(4, 3, &mut rng);
        let b = DenseMatrix::random(5, 3, &mut rng);
        let be = NativeBackend::new();
        let kf = KernelFn::paper_polynomial();
        let tile = be.gram_tile(&a, &b, &kf, &[], &[]);
        for i in 0..4 {
            for j in 0..5 {
                let dot = ops::dot(a.row(i), b.row(j));
                assert!((tile.get(i, j) - kf.apply(dot, 0.0, 0.0)).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn mask_z_selects_assigned_column() {
        let e = DenseMatrix::from_fn(3, 2, |i, j| (i * 2 + j) as f32);
        let be = NativeBackend::new();
        let z = be.mask_z(&e, &[1, 0, 1]);
        assert_eq!(z, vec![1.0, 2.0, 5.0]);
    }

    #[test]
    fn argmin_tie_breaks_low() {
        // Row where clusters 0 and 1 tie exactly.
        let e = DenseMatrix::from_vec(1, 3, vec![1.0, 1.0, 0.0]);
        let c = vec![0.0, 0.0, 0.0];
        let be = NativeBackend::new();
        let (arg, val) = be.distances_argmin(&e, &c);
        assert_eq!(arg, vec![0]);
        assert_eq!(val, vec![-2.0]);
    }

    #[test]
    fn argmin_uses_centroid_norms() {
        // E identical across clusters; c decides.
        let e = DenseMatrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = vec![5.0, 1.0];
        let be = NativeBackend::new();
        let (arg, _) = be.distances_argmin(&e, &c);
        assert_eq!(arg, vec![1, 1]);
    }
}
