//! Machine model: α-β network parameters, device memory budgets, and
//! the paper's analytic communication-cost formulas (Table I).
//!
//! Runtime for the scaling figures is a *hybrid*: per-rank local compute
//! is measured for real (max over ranks = critical path), and
//! communication time is modeled as `rounds·α + crit_bytes·β` from the
//! **exactly counted** critical-path terms recorded by the fabric. Only
//! the network clock is synthetic; volumes and schedules are real.

pub mod analytic;
pub mod mem;

pub use mem::MemTracker;

use crate::comm::stats::{CommStats, PhaseStats};

/// α-β network machine model.
#[derive(Debug, Clone)]
pub struct MachineModel {
    pub name: String,
    /// Per-message latency (seconds).
    pub alpha: f64,
    /// Per-byte transfer time (seconds/byte) = 1 / bandwidth.
    pub beta: f64,
    /// Per-device memory budget in bytes (simulated HBM capacity).
    pub device_mem: u64,
}

impl MachineModel {
    /// Perlmutter-like profile: ~2 µs latency, 25 GB/s effective
    /// per-GPU injection bandwidth (4 NICs / 4 GPUs per node over the
    /// Slingshot dragonfly), 80 GB A100s.
    pub fn perlmutter() -> Self {
        MachineModel {
            name: "perlmutter-a100".into(),
            alpha: 2e-6,
            beta: 1.0 / 25e9,
            device_mem: 80 * (1 << 30) as u64,
        }
    }

    /// Scaled-down profile for laptop-scale experiments: keeps the
    /// paper's α/β *ratio* (latency-vs-bandwidth balance point) but
    /// shrinks device memory so the paper's OOM behaviour reproduces at
    /// our scaled dataset sizes. `mem_scale` divides the 80 GB budget.
    pub fn perlmutter_scaled(mem_scale: u64) -> Self {
        let mut m = Self::perlmutter();
        m.name = format!("perlmutter-a100/mem÷{mem_scale}");
        m.device_mem = (m.device_mem / mem_scale.max(1)).max(1 << 20);
        m
    }

    /// Modeled time of one phase's communication: critical-path rounds
    /// at α plus critical-path bytes at β.
    pub fn comm_time(&self, s: &PhaseStats) -> f64 {
        s.rounds as f64 * self.alpha + s.crit_bytes as f64 * self.beta
    }

    /// Modeled communication time of a whole per-rank ledger, summed
    /// over phases. Callers take the max over ranks for the critical
    /// path.
    pub fn comm_time_total(&self, stats: &CommStats) -> f64 {
        stats.phases().map(|(_, s)| self.comm_time(s)).sum()
    }

    /// Modeled per-phase communication time, critical path over ranks.
    pub fn comm_time_by_phase(&self, all: &[CommStats]) -> Vec<(String, f64)> {
        let merged = CommStats::merged_max(all);
        merged.phases().map(|(k, s)| (k.to_string(), self.comm_time(s))).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perlmutter_params() {
        let m = MachineModel::perlmutter();
        assert!(m.alpha > 0.0 && m.beta > 0.0);
        // 1 MiB at 25 GB/s ~ 42 µs; plus a round of latency.
        let s = PhaseStats { msgs: 1, bytes: 1 << 20, rounds: 1, crit_bytes: 1 << 20 };
        let t = m.comm_time(&s);
        assert!(t > 3e-5 && t < 1e-4, "t={t}");
    }

    #[test]
    fn scaled_memory() {
        let m = MachineModel::perlmutter_scaled(1024);
        assert_eq!(m.device_mem, 80 * (1 << 30) as u64 / 1024);
        assert_eq!(m.alpha, MachineModel::perlmutter().alpha);
    }

    #[test]
    fn comm_time_sums_phases() {
        let m = MachineModel { name: "t".into(), alpha: 1.0, beta: 1.0, device_mem: 0 };
        let mut cs = CommStats::new();
        cs.record("a", PhaseStats { msgs: 0, bytes: 0, rounds: 2, crit_bytes: 3 });
        cs.record("b", PhaseStats { msgs: 0, bytes: 0, rounds: 1, crit_bytes: 1 });
        assert_eq!(m.comm_time_total(&cs), 7.0);
    }
}
