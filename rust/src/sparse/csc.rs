//! General compressed-sparse-column matrix (f32).
//!
//! Used by tests as the explicit form of V (the paper stores local V
//! partitions in CSC, §V) and to validate the structured kernels in
//! [`super::ops`] against a general SpMM.

use crate::dense::DenseMatrix;

/// CSC sparse matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct CscMatrix {
    rows: usize,
    cols: usize,
    /// Column pointers, len = cols + 1.
    colptr: Vec<usize>,
    /// Row indices, len = nnz.
    rowidx: Vec<u32>,
    /// Values, len = nnz.
    values: Vec<f32>,
}

impl CscMatrix {
    pub fn new(rows: usize, cols: usize, colptr: Vec<usize>, rowidx: Vec<u32>, values: Vec<f32>) -> Self {
        assert_eq!(colptr.len(), cols + 1);
        assert_eq!(*colptr.last().unwrap(), rowidx.len());
        assert_eq!(rowidx.len(), values.len());
        for w in colptr.windows(2) {
            assert!(w[0] <= w[1], "colptr not monotone");
        }
        assert!(rowidx.iter().all(|&r| (r as usize) < rows), "row index out of range");
        CscMatrix { rows, cols, colptr, rowidx, values }
    }

    /// Build from (row, col, value) triplets (unsorted OK; duplicates
    /// summed).
    pub fn from_triplets(rows: usize, cols: usize, triplets: &[(usize, usize, f32)]) -> Self {
        let mut per_col: Vec<Vec<(u32, f32)>> = vec![Vec::new(); cols];
        for &(r, c, v) in triplets {
            assert!(r < rows && c < cols);
            per_col[c].push((r as u32, v));
        }
        let mut colptr = Vec::with_capacity(cols + 1);
        let mut rowidx = Vec::new();
        let mut values = Vec::new();
        colptr.push(0);
        for col in &mut per_col {
            col.sort_by_key(|(r, _)| *r);
            let mut i = 0;
            while i < col.len() {
                let r = col[i].0;
                let mut v = 0.0;
                while i < col.len() && col[i].0 == r {
                    v += col[i].1;
                    i += 1;
                }
                rowidx.push(r);
                values.push(v);
            }
            colptr.push(rowidx.len());
        }
        CscMatrix { rows, cols, colptr, rowidx, values }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.rowidx.len()
    }

    pub fn colptr(&self) -> &[usize] {
        &self.colptr
    }

    pub fn rowidx(&self) -> &[u32] {
        &self.rowidx
    }

    pub fn values(&self) -> &[f32] {
        &self.values
    }

    /// Entries of column j as (row, value) pairs.
    pub fn col(&self, j: usize) -> impl Iterator<Item = (u32, f32)> + '_ {
        let lo = self.colptr[j];
        let hi = self.colptr[j + 1];
        self.rowidx[lo..hi].iter().copied().zip(self.values[lo..hi].iter().copied())
    }

    /// Dense conversion (tests).
    pub fn to_dense(&self) -> DenseMatrix {
        let mut out = DenseMatrix::zeros(self.rows, self.cols);
        for j in 0..self.cols {
            for (r, v) in self.col(j) {
                out.set(r as usize, j, v);
            }
        }
        out
    }

    /// General SpMM: self (m×n) · dense (n×q) -> dense (m×q).
    pub fn spmm(&self, dense: &DenseMatrix) -> DenseMatrix {
        assert_eq!(self.cols, dense.rows(), "spmm: dims");
        let mut out = DenseMatrix::zeros(self.rows, dense.cols());
        for j in 0..self.cols {
            for (r, v) in self.col(j) {
                let dst_start = r as usize * dense.cols();
                let src = dense.row(j);
                let dst = &mut out.data_mut()[dst_start..dst_start + src.len()];
                for (d, s) in dst.iter_mut().zip(src) {
                    *d += v * s;
                }
            }
        }
        out
    }

    /// SpMV: self (m×n) · x (n) -> y (m).
    pub fn spmv(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(self.cols, x.len());
        let mut y = vec![0.0f32; self.rows];
        for j in 0..self.cols {
            for (r, v) in self.col(j) {
                y[r as usize] += v * x[j];
            }
        }
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CscMatrix {
        // [[1, 0, 2],
        //  [0, 3, 0]]
        CscMatrix::from_triplets(2, 3, &[(0, 0, 1.0), (1, 1, 3.0), (0, 2, 2.0)])
    }

    #[test]
    fn construction() {
        let m = sample();
        assert_eq!(m.nnz(), 3);
        assert_eq!(m.to_dense().get(1, 1), 3.0);
        assert_eq!(m.to_dense().get(0, 2), 2.0);
        assert_eq!(m.to_dense().get(1, 2), 0.0);
    }

    #[test]
    fn duplicates_summed() {
        let m = CscMatrix::from_triplets(2, 2, &[(0, 0, 1.0), (0, 0, 2.0)]);
        assert_eq!(m.nnz(), 1);
        assert_eq!(m.to_dense().get(0, 0), 3.0);
    }

    #[test]
    fn spmm_matches_dense() {
        let m = sample();
        let d = DenseMatrix::from_fn(3, 2, |i, j| (i + j) as f32);
        let out = m.spmm(&d);
        // row 0: 1*d[0,:] + 2*d[2,:] ; row 1: 3*d[1,:]
        assert_eq!(out.get(0, 0), 1.0 * 0.0 + 2.0 * 2.0);
        assert_eq!(out.get(0, 1), 1.0 * 1.0 + 2.0 * 3.0);
        assert_eq!(out.get(1, 0), 3.0 * 1.0);
        assert_eq!(out.get(1, 1), 3.0 * 2.0);
    }

    #[test]
    fn spmv_basic() {
        let m = sample();
        let y = m.spmv(&[1.0, 2.0, 3.0]);
        assert_eq!(y, vec![1.0 + 6.0, 6.0]);
    }

    #[test]
    #[should_panic]
    fn invalid_row_index_rejected() {
        let _ = CscMatrix::new(2, 1, vec![0, 1], vec![5], vec![1.0]);
    }
}
