"""L2: the per-rank compute graph of distributed Kernel K-means.

Composes the L1 Pallas kernels (``kernels/``) into the jit-able
functions the Rust coordinator calls through PJRT:

  * ``gram_tile_*``  — K tile = κ(P_i · P_jᵀ)            (Eqs. 1–2)
  * ``kernel_apply_*`` — SUMMA elementwise epilogue
  * ``spmm_vk`` / ``spmm_vk_t`` — structured SpMM          (Eq. 4)
  * ``update_pre``   — fused mask + local SpMV → partial c (Eqs. 5–6)
  * ``update_post``  — fused distances + argmin            (Eq. 8)
  * ``cluster_iter_local`` — the whole communication-free part of one
    1D-layout iteration (SpMM → pre), demonstrating XLA fusion across
    kernels; the Allreduce of c happens in Rust between ``pre`` and
    ``post``.

Everything here is build-time only: ``aot.py`` lowers these functions
at the manifest's shapes to HLO text; Python never runs at serving
time.
"""

import jax.numpy as jnp

from .kernels import distance, gram, spmm


# --- K computation -------------------------------------------------------

def gram_tile_linear(a, b):
    return gram.gram_tile(a, b, kind="linear")


def gram_tile_poly(a, b, gamma=1.0, c=1.0, degree=2.0):
    """The paper's benchmark kernel (γ=1, c=1, d=2) by default."""
    return gram.gram_tile(a, b, kind="poly", gamma=gamma, c=c, degree=degree)


def gram_tile_rbf(a, b, gamma=1.0):
    return gram.gram_tile(a, b, kind="rbf", gamma=gamma)


def kernel_apply_poly(b, gamma=1.0, c=1.0, degree=2.0):
    return gram.kernel_apply(b, kind="poly", gamma=gamma, c=c, degree=degree)


def kernel_apply_rbf(b, row_norms, col_norms, gamma=1.0):
    """RBF epilogue needs norms; plain jnp (elementwise, XLA fuses it)."""
    d2 = row_norms[:, None] + col_norms[None, :] - 2.0 * b
    return jnp.exp(-gamma * d2)


# --- clustering loop ------------------------------------------------------

def spmm_vk(k_tile, assign, inv_sizes):
    return spmm.spmm_vk(k_tile, assign, inv_sizes)


def spmm_vk_t(k_tile, assign, inv_sizes):
    return spmm.spmm_vk_t(k_tile, assign, inv_sizes)


def update_pre(e, assign, inv_sizes):
    return distance.update_pre(e, assign, inv_sizes)


def update_post(e, c):
    return distance.update_post(e, c)


def cluster_iter_local(k_block_row, assign_all, assign_own, inv_sizes):
    """The communication-free half of one 1D iteration.

    k_block_row: (m, n) — this rank's block row of K.
    assign_all: (n,) i32 — allgathered assignments.
    assign_own: (m,) i32 — this rank's slice (for the mask).
    Returns (E (m,k), partial c (k,)). The coordinator allreduces c and
    then calls ``update_post``.
    """
    e = spmm.spmm_vk(k_block_row, assign_all, inv_sizes)
    c_part = distance.update_pre(e, assign_own, inv_sizes)
    return e, c_part
