//! Device-service threads: own the PJRT client + compiled executables,
//! serve execution requests from coordinator ranks over channels.
//!
//! PJRT handles are thread-affine (`!Send`), so each service thread
//! compiles its own copy of every artifact on its own
//! `PjRtClient::cpu()` — the analogue of one GPU with its own context.
//! Ranks round-robin across services.

#[cfg(feature = "xla")]
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;

use super::manifest::{Dtype, Manifest, TensorSpec};

/// A host-side tensor crossing the service channel.
#[derive(Debug, Clone)]
pub enum HostTensor {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl HostTensor {
    pub fn spec(&self) -> TensorSpec {
        match self {
            HostTensor::F32(_, s) => TensorSpec { shape: s.clone(), dtype: Dtype::F32 },
            HostTensor::I32(_, s) => TensorSpec { shape: s.clone(), dtype: Dtype::I32 },
        }
    }

    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            HostTensor::F32(v, _) => Some(v),
            _ => None,
        }
    }

    pub fn as_i32(&self) -> Option<&[i32]> {
        match self {
            HostTensor::I32(v, _) => Some(v),
            _ => None,
        }
    }
}

/// An execution argument: inline data, or a reference to a buffer the
/// service has cached device-side (the K tile is uploaded once per fit
/// and referenced by fingerprint for the remaining ~100 iterations —
/// the §Perf "device-resident operands" optimization).
#[derive(Debug, Clone)]
pub enum Arg {
    Inline(HostTensor),
    Cached { fp: u64, spec: TensorSpec },
}

impl Arg {
    fn spec(&self) -> TensorSpec {
        match self {
            Arg::Inline(t) => t.spec(),
            Arg::Cached { spec, .. } => spec.clone(),
        }
    }
}

enum Request {
    Exec {
        op: String,
        args: Vec<Arg>,
        reply: mpsc::SyncSender<Result<Vec<HostTensor>, String>>,
    },
    Has {
        fp: u64,
        reply: mpsc::SyncSender<bool>,
    },
    Put {
        fp: u64,
        tensor: HostTensor,
        reply: mpsc::SyncSender<Result<(), String>>,
    },
}

/// Handle to a pool of device-service threads.
///
/// Dropping the handle shuts the threads down **and joins them**, so
/// PJRT client destruction never races process teardown.
pub struct DeviceService {
    senders: Vec<mpsc::Sender<Request>>,
    next: AtomicUsize,
    /// (op, file) pairs served (same on every service thread).
    ops: Vec<(String, Vec<TensorSpec>)>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl Drop for DeviceService {
    fn drop(&mut self) {
        self.senders.clear(); // closes channels; service loops exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl DeviceService {
    /// Spawn `n_devices` service threads, each compiling every artifact
    /// in the manifest. Returns once all threads finished compiling (or
    /// the first error).
    ///
    /// Built without the `xla` feature (the dependency-free default),
    /// this reports the runtime as unavailable; callers fall back to
    /// the native backend exactly as they do when artifacts are absent.
    #[cfg(not(feature = "xla"))]
    pub fn start(_manifest: &Manifest, _n_devices: usize) -> Result<DeviceService, String> {
        Err("PJRT runtime unavailable: built without the `xla` feature \
             (vendor the xla_extension bindings and enable it)"
            .to_string())
    }

    /// Spawn `n_devices` service threads, each compiling every artifact
    /// in the manifest. Returns once all threads finished compiling (or
    /// the first error).
    #[cfg(feature = "xla")]
    pub fn start(manifest: &Manifest, n_devices: usize) -> Result<DeviceService, String> {
        let n = n_devices.max(1);
        let mut senders = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
        for dev in 0..n {
            let (tx, rx) = mpsc::channel::<Request>();
            senders.push(tx);
            let mani = manifest.clone();
            let ready = ready_tx.clone();
            let h = std::thread::Builder::new()
                .name(format!("pjrt-dev-{dev}"))
                .spawn(move || service_main(mani, rx, ready))
                .map_err(|e| e.to_string())?;
            handles.push(h);
        }
        drop(ready_tx);
        for _ in 0..n {
            ready_rx.recv().map_err(|e| e.to_string())??;
        }
        Ok(DeviceService {
            senders,
            next: AtomicUsize::new(0),
            ops: manifest.ops.iter().map(|e| (e.op.clone(), e.inputs.clone())).collect(),
            handles,
        })
    }

    /// Whether (op, input specs) has a compiled executable.
    pub fn has(&self, op: &str, specs: &[TensorSpec]) -> bool {
        self.ops.iter().any(|(o, s)| o == op && s == specs)
    }

    /// Execute an op; blocks until the device thread replies.
    pub fn execute(&self, op: &str, inputs: Vec<HostTensor>) -> Result<Vec<HostTensor>, String> {
        let idx = self.next.fetch_add(1, Ordering::Relaxed) % self.senders.len();
        self.execute_on(idx, op, inputs.into_iter().map(Arg::Inline).collect())
    }

    /// Execute with explicit args (inline and/or cached) on the device
    /// owning `route_fp`'s cache entry.
    pub fn execute_cached(
        &self,
        route_fp: u64,
        op: &str,
        args: Vec<Arg>,
    ) -> Result<Vec<HostTensor>, String> {
        self.execute_on(self.device_for(route_fp), op, args)
    }

    /// Which service thread caches fingerprint `fp`.
    pub fn device_for(&self, fp: u64) -> usize {
        (fp as usize) % self.senders.len()
    }

    /// Is `fp` uploaded on its home device?
    pub fn has_cached(&self, fp: u64) -> bool {
        let (tx, rx) = mpsc::sync_channel(1);
        if self.senders[self.device_for(fp)].send(Request::Has { fp, reply: tx }).is_err() {
            return false;
        }
        rx.recv().unwrap_or(false)
    }

    /// Upload a tensor to its home device cache.
    pub fn put_cached(&self, fp: u64, tensor: HostTensor) -> Result<(), String> {
        let (tx, rx) = mpsc::sync_channel(1);
        self.senders[self.device_for(fp)]
            .send(Request::Put { fp, tensor, reply: tx })
            .map_err(|_| "device service stopped".to_string())?;
        rx.recv().map_err(|_| "device service dropped reply".to_string())?
    }

    fn execute_on(
        &self,
        idx: usize,
        op: &str,
        args: Vec<Arg>,
    ) -> Result<Vec<HostTensor>, String> {
        let (reply_tx, reply_rx) = mpsc::sync_channel(1);
        self.senders[idx]
            .send(Request::Exec { op: op.to_string(), args, reply: reply_tx })
            .map_err(|_| "device service stopped".to_string())?;
        reply_rx.recv().map_err(|_| "device service dropped reply".to_string())?
    }
}

/// Content fingerprint for device-buffer caching: length/shape plus a
/// strided sample of values. Collisions require equal shapes AND equal
/// samples — adequate for the immutable K tiles this caches.
pub fn fingerprint_f32(data: &[f32], shape: &[usize]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    let mut mix = |v: u64| {
        h ^= v;
        h = h.wrapping_mul(0x100000001b3);
    };
    mix(data.len() as u64);
    for &s in shape {
        mix(s as u64);
    }
    let step = (data.len() / 64).max(1);
    for i in (0..data.len()).step_by(step) {
        mix(data[i].to_bits() as u64);
    }
    if let Some(last) = data.last() {
        mix(last.to_bits() as u64);
    }
    h
}

#[cfg(feature = "xla")]
fn tensor_of(lit: &xla::Literal, spec: &TensorSpec) -> Result<HostTensor, String> {
    match spec.dtype {
        Dtype::F32 => Ok(HostTensor::F32(
            lit.to_vec::<f32>().map_err(|e| e.to_string())?,
            spec.shape.clone(),
        )),
        Dtype::I32 => Ok(HostTensor::I32(
            lit.to_vec::<i32>().map_err(|e| e.to_string())?,
            spec.shape.clone(),
        )),
    }
}

/// xla_extension 0.5.1's CPU client is not safe to create/destroy
/// concurrently from multiple threads in one process; all client
/// lifecycle events serialize on this lock (execution is fine).
#[cfg(feature = "xla")]
static PJRT_LIFECYCLE: std::sync::Mutex<()> = std::sync::Mutex::new(());

#[cfg(feature = "xla")]
fn service_main(
    manifest: Manifest,
    rx: mpsc::Receiver<Request>,
    ready: mpsc::Sender<Result<(), String>>,
) {
    // Compile everything once (client creation under the lifecycle
    // lock).
    let guard = PJRT_LIFECYCLE.lock().unwrap();
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            drop(guard);
            let _ = ready.send(Err(format!("PjRtClient::cpu: {e}")));
            return;
        }
    };
    let mut exes: HashMap<(String, Vec<TensorSpec>), (xla::PjRtLoadedExecutable, Vec<TensorSpec>)> =
        HashMap::new();
    for entry in &manifest.ops {
        let proto = match xla::HloModuleProto::from_text_file(entry.file.to_str().unwrap_or("")) {
            Ok(p) => p,
            Err(e) => {
                let _ = ready.send(Err(format!("parse {}: {e}", entry.file.display())));
                return;
            }
        };
        let comp = xla::XlaComputation::from_proto(&proto);
        match client.compile(&comp) {
            Ok(exe) => {
                exes.insert((entry.op.clone(), entry.inputs.clone()), (exe, entry.outputs.clone()));
            }
            Err(e) => {
                let _ = ready.send(Err(format!("compile {}: {e}", entry.file.display())));
                return;
            }
        }
    }
    drop(guard);
    let _ = ready.send(Ok(()));

    let mut bufcache: HashMap<u64, xla::PjRtBuffer> = HashMap::new();
    while let Ok(req) = rx.recv() {
        match req {
            Request::Has { fp, reply } => {
                let _ = reply.send(bufcache.contains_key(&fp));
            }
            Request::Put { fp, tensor, reply } => {
                let result = (|| -> Result<(), String> {
                    let buf = match &tensor {
                        HostTensor::F32(v, shape) => client
                            .buffer_from_host_buffer(v, shape, None)
                            .map_err(|e| e.to_string())?,
                        HostTensor::I32(v, shape) => client
                            .buffer_from_host_buffer(v, shape, None)
                            .map_err(|e| e.to_string())?,
                    };
                    bufcache.insert(fp, buf);
                    Ok(())
                })();
                let _ = reply.send(result);
            }
            Request::Exec { op, args, reply } => {
                let specs: Vec<TensorSpec> = args.iter().map(|a| a.spec()).collect();
                let result = (|| -> Result<Vec<HostTensor>, String> {
                    let (exe, out_specs) = exes
                        .get(&(op.clone(), specs.clone()))
                        .ok_or_else(|| format!("no executable for {op} {specs:?}"))?;
                    // Assemble device buffers: cached refs resolve from
                    // the cache, inline args upload on the spot.
                    let mut owned: Vec<xla::PjRtBuffer> = Vec::new();
                    let mut order: Vec<usize> = Vec::new(); // index into owned or cache marker
                    let mut cached_refs: Vec<u64> = Vec::new();
                    for a in &args {
                        match a {
                            Arg::Inline(t) => {
                                let buf = match t {
                                    HostTensor::F32(v, shape) => client
                                        .buffer_from_host_buffer(v, shape, None)
                                        .map_err(|e| e.to_string())?,
                                    HostTensor::I32(v, shape) => client
                                        .buffer_from_host_buffer(v, shape, None)
                                        .map_err(|e| e.to_string())?,
                                };
                                owned.push(buf);
                                order.push(owned.len()); // >0 = owned[i-1]
                                cached_refs.push(0);
                            }
                            Arg::Cached { fp, .. } => {
                                if !bufcache.contains_key(fp) {
                                    return Err(format!("no cached buffer {fp:#x}"));
                                }
                                order.push(0); // 0 = cached
                                cached_refs.push(*fp);
                            }
                        }
                    }
                    let mut owned_iter = 0usize;
                    let buf_args: Vec<&xla::PjRtBuffer> = order
                        .iter()
                        .zip(&cached_refs)
                        .map(|(&o, fp)| {
                            if o == 0 {
                                &bufcache[fp]
                            } else {
                                let b = &owned[owned_iter];
                                owned_iter += 1;
                                b
                            }
                        })
                        .collect();
                    let out = exe.execute_b::<&xla::PjRtBuffer>(&buf_args).map_err(|e| e.to_string())?;
                    let root = out[0][0].to_literal_sync().map_err(|e| e.to_string())?;
                    // Lowered with return_tuple=True: unwrap the tuple.
                    let parts = root.to_tuple().map_err(|e| e.to_string())?;
                    if parts.len() != out_specs.len() {
                        return Err(format!(
                            "output arity mismatch: {} vs {}",
                            parts.len(),
                            out_specs.len()
                        ));
                    }
                    parts.iter().zip(out_specs).map(|(l, s)| tensor_of(l, s)).collect()
                })();
                let _ = reply.send(result);
            }
        }
    }
    // Teardown under the lifecycle lock: buffers, executables, then the
    // client — never concurrent with another thread's create/destroy.
    let _guard = PJRT_LIFECYCLE.lock().unwrap();
    drop(bufcache);
    drop(exes);
    drop(client);
}

#[cfg(test)]
mod tests {
    use super::*;

    /// End-to-end artifact execution (skipped when artifacts absent).
    #[test]
    fn executes_real_artifact() {
        let dir = crate::runtime::artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let manifest = Manifest::load(&dir).unwrap();
        // Pick the smallest update_post entry.
        let entry = manifest
            .ops
            .iter()
            .filter(|e| e.op == "update_post")
            .min_by_key(|e| e.inputs[0].shape.iter().product::<usize>())
            .expect("manifest has update_post");
        let svc = DeviceService::start(&manifest, 1).unwrap();
        let m = entry.inputs[0].shape[0];
        let k = entry.inputs[0].shape[1];
        // E with a clear winner per row; c = 0.
        let mut e = vec![0.0f32; m * k];
        for j in 0..m {
            e[j * k + (j % k)] = 10.0; // argmin of -2E+c is j%k
        }
        let out = svc
            .execute(
                "update_post",
                vec![
                    HostTensor::F32(e, vec![m, k]),
                    HostTensor::F32(vec![0.0; k], vec![k]),
                ],
            )
            .unwrap();
        assert_eq!(out.len(), 2);
        let am = out[0].as_i32().unwrap();
        for j in 0..m {
            assert_eq!(am[j] as usize, j % k, "row {j}");
        }
        let mv = out[1].as_f32().unwrap();
        assert!((mv[0] + 20.0).abs() < 1e-5);
    }

    #[test]
    fn unknown_op_errors() {
        let dir = crate::runtime::artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return;
        }
        let manifest = Manifest::load(&dir).unwrap();
        let svc = DeviceService::start(&manifest, 1).unwrap();
        let err = svc.execute("nonexistent", vec![HostTensor::F32(vec![1.0], vec![1])]);
        assert!(err.is_err());
    }
}
