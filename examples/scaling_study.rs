//! END-TO-END DRIVER: the full three-layer stack on a real small
//! workload.
//!
//! Proves all layers compose: Pallas kernels (L1) were AOT-lowered by
//! `make artifacts` into HLO text; this binary loads them through the
//! PJRT runtime (L2 artifacts served by device-service threads) and
//! runs the paper's four distributed algorithms (L3 coordinator) on an
//! MNIST8m-like workload, reporting the paper's headline metrics:
//! 1.5D-vs-1D speedup, the per-phase breakdown, the objective curve,
//! clustering quality, and the PJRT artifact hit rate (Python never
//! runs here).
//!
//! Run: `make artifacts && cargo run --release --example scaling_study`
//! Results are recorded in EXPERIMENTS.md §End-to-end.

use vivaldi::comm::CommStats;
use vivaldi::data::datasets::PaperDataset;
use vivaldi::kkmeans::{self, Algo, FitConfig};
use vivaldi::metrics::Table;
use vivaldi::model::MachineModel;
use vivaldi::quality;
use vivaldi::runtime::PjrtBackend;

fn main() {
    // The artifact manifest's default scale: n=4096, d=64, k=16, √P=2.
    let (n, d, k, g) = (4096usize, 64usize, 16usize, 4usize);
    let ds = PaperDataset::Mnist8mLike.generate(n, Some(d), 20260710);
    println!("workload: {} — n={} d={} k={k} on G={g} simulated ranks", ds.name, ds.n(), ds.d());

    let pjrt: Option<PjrtBackend> = if vivaldi::runtime::artifacts_available() {
        match PjrtBackend::from_default_artifacts(2) {
            Ok(be) => {
                println!("backend: PJRT (AOT artifacts, 2 device-service threads)");
                Some(be)
            }
            Err(e) => {
                println!("backend: native (pjrt unavailable: {e})");
                None
            }
        }
    } else {
        println!("backend: native (run `make artifacts` for the PJRT path)");
        None
    };
    let native = vivaldi::backend::NativeBackend::new();
    let backend: &dyn vivaldi::backend::ComputeBackend = match &pjrt {
        Some(be) => be,
        None => &native,
    };

    let cfg = FitConfig { k, max_iters: 30, converge_on_stable: true, ..Default::default() };
    let machine = MachineModel::perlmutter();

    let mut table = Table::new(
        "End-to-end: four algorithms, same workload (wall seconds on this host)",
        &["algo", "wall s", "iters", "NMI", "comm msgs", "comm bytes", "modeled comm s"],
    );
    let mut objective_curve: Vec<f64> = Vec::new();
    let mut wall_1d = 0.0f64;
    let mut wall_15d = 0.0f64;

    for algo in [Algo::OneD, Algo::HybridOneD, Algo::TwoD, Algo::OneFiveD] {
        let t0 = std::time::Instant::now();
        let out = kkmeans::fit_with_backend(algo, g, &ds.points, &cfg, backend).expect("fit");
        let wall = t0.elapsed().as_secs_f64();
        let nmi = quality::nmi(&out.assignments, &ds.labels, k);
        let total = CommStats::merged_sum(&out.comm_stats).total();
        let modeled: f64 = out
            .comm_stats
            .iter()
            .map(|s| machine.comm_time_total(s))
            .fold(0.0f64, f64::max);
        table.row(vec![
            algo.name().into(),
            format!("{wall:.3}"),
            out.iterations.to_string(),
            format!("{nmi:.3}"),
            total.msgs.to_string(),
            vivaldi::util::human_bytes(total.bytes),
            format!("{modeled:.5}"),
        ]);
        if algo == Algo::OneD {
            wall_1d = wall;
        }
        if algo == Algo::OneFiveD {
            wall_15d = wall;
            objective_curve = out.objective_curve.clone();
        }
    }
    table.print();

    // The "loss curve": relative kernel-k-means objective per iteration.
    println!("1.5D objective curve (relative, monotone ↓):");
    for (i, o) in objective_curve.iter().enumerate() {
        println!("  iter {:>2}  {o:.2}", i + 1);
    }
    for w in objective_curve.windows(2) {
        assert!(w[1] <= w[0] + 1e-2, "objective must not increase: {w:?}");
    }

    println!("\nheadline: 1.5D vs 1D wall time = {:.2}x (paper: up to 3.6x at 256 GPUs)", wall_1d / wall_15d);
    if let Some(be) = &pjrt {
        let (hits, misses) = be.counters();
        println!("pjrt: {hits} artifact executions, {misses} native fallbacks");
    }
    println!("OK — all layers composed (Pallas → HLO → PJRT → coordinator).");
}
