//! 1D Allgather GEMM: K_p = κ(P_p · Pᵀ) with P fully replicated.
//!
//! The baseline the paper's prior-work approaches reduce to. Every rank
//! allgathers the entire point matrix (O(P·n·d) total words — the
//! volume does not shrink with P) and computes its block row of K
//! locally. Memory: the replicated P plus the local K block row —
//! exactly the footprint that OOMs for KDD's d = 10000 in the paper's
//! Fig. 2 discussion, reproduced here via the [`MemTracker`] budget.

use crate::backend::ComputeBackend;
use crate::comm::{Comm, Group};
use crate::dense::DenseMatrix;
use crate::kernelfn::KernelFn;
use crate::model::MemTracker;
use crate::VivaldiError;

/// Compute this rank's block row of K.
///
/// `local_points`: this rank's (m_p × d) slice of P (1D row blocks in
/// rank order). Returns K_p = κ(P_p·Pᵀ), shape m_p × n.
///
/// The big allocations (replicated P, K_p) are registered against
/// `tracker`; failure is detected **collectively** (AND-allreduce) so
/// every rank returns `OutOfMemory` together instead of deadlocking.
pub fn gemm_1d_gram(
    comm: &Comm,
    world: &Group,
    local_points: &DenseMatrix,
    kernel: &KernelFn,
    backend: &dyn ComputeBackend,
    tracker: &MemTracker,
    repl_factor: f64,
) -> Result<DenseMatrix, VivaldiError> {
    comm.set_phase("gemm");
    let d = local_points.cols();
    let m = local_points.rows();

    // Collective memory check before the allgather: full P + K block.
    let n_total: u64 = {
        let counts = comm.allreduce_sum_u64(world, vec![m as u64]);
        counts[0]
    };
    // The replicated-P charge is scaled by `repl_factor` (calibrated
    // memory model, see crate::config::MemModel; 1.0 = actual bytes).
    let need = (MemTracker::matrix_f32(n_total as usize, d) as f64 * repl_factor) as u64
        + MemTracker::matrix_f32(m, n_total as usize);
    let ok = tracker.try_alloc(need, "1D GEMM: replicated P + K block row");
    if !comm.allreduce_and(world, ok) {
        if ok {
            tracker.free(need);
        }
        return Err(VivaldiError::OutOfMemory {
            rank: comm.rank(),
            requested: need,
            budget: tracker.budget(),
            what: "1D GEMM: replicated P + K block row".into(),
        });
    }

    // Allgather P (the expensive replication).
    let full_p_data = comm.allgather_concat(world, local_points.data().to_vec());
    let full_p = DenseMatrix::from_vec(n_total as usize, d, full_p_data);

    // Norms only needed for distance-based kernels.
    let (row_norms, col_norms) = if kernel.needs_norms() {
        (local_points.row_sq_norms(), full_p.row_sq_norms())
    } else {
        (Vec::new(), Vec::new())
    };

    let k_block = backend.gram_tile(local_points, &full_p, kernel, &row_norms, &col_norms);
    // The replicated P is freed after the GEMM (K block row stays).
    tracker.free((MemTracker::matrix_f32(n_total as usize, d) as f64 * repl_factor) as u64);
    Ok(k_block)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::comm::World;
    use crate::util::{part, rng::Rng};

    fn oracle_k(points: &DenseMatrix, kernel: &KernelFn) -> DenseMatrix {
        let be = NativeBackend::new();
        let norms = points.row_sq_norms();
        be.gram_tile(points, points, kernel, &norms, &norms)
    }

    #[test]
    fn matches_oracle_across_rank_counts() {
        let mut rng = Rng::new(21);
        let n = 37;
        let d = 5;
        let points = DenseMatrix::random(n, d, &mut rng);
        for kernel in [KernelFn::linear(), KernelFn::paper_polynomial(), KernelFn::gaussian(0.3)]
        {
            let expect = oracle_k(&points, &kernel);
            for p in [1usize, 2, 4, 5] {
                let pref = &points;
                let kref = &kernel;
                let (blocks, _) = World::run(p, |comm| {
                    let world = Group::world(p);
                    let (lo, hi) = part::bounds(n, p, comm.rank());
                    let local = pref.row_block(lo, hi);
                    let be = NativeBackend::new();
                    let tracker = MemTracker::unlimited(comm.rank());
                    gemm_1d_gram(comm, &world, &local, kref, &be, &tracker, 1.0).unwrap()
                });
                let k_full = DenseMatrix::vstack(&blocks);
                assert!(
                    k_full.max_abs_diff(&expect) < 1e-3,
                    "kernel={kernel:?} p={p}"
                );
            }
        }
    }

    #[test]
    fn volume_grows_with_p() {
        // The defining 1D weakness: allgather volume scales with P.
        let mut rng = Rng::new(22);
        let n = 32;
        let d = 8;
        let points = DenseMatrix::random(n, d, &mut rng);
        let mut volumes = Vec::new();
        for p in [2usize, 4, 8] {
            let pref = &points;
            let (_, stats) = World::run(p, |comm| {
                let world = Group::world(p);
                let (lo, hi) = part::bounds(n, p, comm.rank());
                let local = pref.row_block(lo, hi);
                let be = NativeBackend::new();
                let tracker = MemTracker::unlimited(comm.rank());
                gemm_1d_gram(comm, &world, &local, &KernelFn::linear(), &be, &tracker, 1.0).unwrap()
            });
            let total: u64 = stats.iter().map(|s| s.get("gemm").bytes).sum();
            volumes.push(total);
        }
        // Ring allgather: each rank forwards ~the whole matrix, so the
        // total volume is ≈ (P-1)·n·d·4 — strictly increasing in P.
        assert!(volumes[1] > volumes[0]);
        assert!(volumes[2] > volumes[1]);
    }

    #[test]
    fn collective_oom() {
        let n = 64;
        let d = 16;
        let mut rng = Rng::new(23);
        let points = DenseMatrix::random(n, d, &mut rng);
        let p = 4;
        let pref = &points;
        let (results, _) = World::run(p, |comm| {
            let world = Group::world(p);
            let (lo, hi) = part::bounds(n, p, comm.rank());
            let local = pref.row_block(lo, hi);
            let be = NativeBackend::new();
            // Budget too small for replicated P (64*16*4 = 4096 B).
            let tracker = MemTracker::new(comm.rank(), 1024);
            gemm_1d_gram(comm, &world, &local, &KernelFn::linear(), &be, &tracker, 1.0)
        });
        for r in results {
            assert!(matches!(r, Err(VivaldiError::OutOfMemory { .. })));
        }
    }
}
