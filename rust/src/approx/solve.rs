//! Small dense SPD factorization for the reduced-rank cluster update.
//!
//! The landmark update solves `(W + λI) α_a = c̄_a` for every cluster,
//! where `W = κ(L, L)` is m×m with m ≪ n. `W` can be numerically
//! rank-deficient (a linear kernel has rank ≤ d; polynomial kernels are
//! often ill-conditioned in f32), so the factorization is a **ridge-
//! regularized f64 Cholesky with deterministic escalation**: start from
//! λ = 1e-8·tr(W)/m and multiply by 10 until the factorization
//! succeeds. Everything is deterministic and rank-replicated — every
//! rank factors the same W and obtains bit-identical coefficients.
//!
//! Two solvers implement that contract:
//!
//! * [`SpdSolver`] — the replicated scalar factorization (every caller
//!   holds full W).
//! * [`DistSpdSolver`] — the same factorization **distributed over the
//!   1.5D grid's diagonal group**: W lives as block-cyclic column
//!   panels ([`BlockCyclic`]), the Cholesky runs as panel
//!   factorization + panel broadcast + trailing update, and the
//!   per-iteration solves run as pipelined forward/back substitution
//!   against the distributed factor. No rank ever holds more than
//!   ~m²/q of W (plus one broadcast panel in flight). The substitution
//!   token is **active-set restricted**: only clusters with nonzero
//!   weight travel, and only the live row range of each sweep (the
//!   forward token shrinks as y values finalize, the backward token
//!   grows as x values finalize) — roughly halving the solve-phase
//!   volume at full occupancy and shrinking it further with every
//!   empty cluster, at zero arithmetic cost.
//!
//! [`host_solve_alpha_weighted_panels`] is the driver-side companion:
//! it solves against a complete panel set (all q diagonal solvers)
//! without assembling the factor, so the streaming driver can classify
//! tail batches after the distributed stream-init dropped its m² host
//! copy of W.
//!
//! **Bit-identity invariant:** for every element, both solvers perform
//! the identical sequence of f64 operations in the identical order —
//! the trailing updates subtract `l[i][t]·l[j][t]` one `t` at a time in
//! ascending `t`, exactly like the scalar loop — so `DistSpdSolver`
//! produces bit-identical factors, coefficients, and center norms to
//! `SpdSolver` on the same W. The test wall pins this with exact `==`
//! on the f64 outputs.

use crate::comm::{Comm, Group};
use crate::dense::DenseMatrix;
use crate::layout::BlockCyclic;

/// Cholesky factor of `W + λI` (f64), reused across iterations: `W` is
/// fixed for a whole fit, only the right-hand sides change.
#[derive(Debug, Clone)]
pub struct SpdSolver {
    /// Lower-triangular factor, row-major m×m.
    l: Vec<f64>,
    m: usize,
    /// The ridge that made the factorization succeed.
    pub ridge: f64,
}

impl SpdSolver {
    /// Factor `w + λI` with the escalating deterministic ridge.
    ///
    /// Panics only if no ridge up to ~1e12·tr(W)/m works, which cannot
    /// happen for finite symmetric input (the matrix becomes diagonally
    /// dominant long before that).
    pub fn factor(w: &DenseMatrix) -> SpdSolver {
        let m = w.rows();
        assert_eq!(w.cols(), m, "SpdSolver: square matrix required");
        assert!(m >= 1);
        let trace: f64 = (0..m).map(|i| w.get(i, i) as f64).sum();
        let base = (trace / m as f64).abs().max(1e-12);
        let mut ridge = 1e-8 * base;
        for _ in 0..24 {
            if let Some(l) = try_cholesky(w, ridge) {
                return SpdSolver { l, m, ridge };
            }
            ridge *= 10.0;
        }
        panic!("SpdSolver: no ridge stabilized the {m}x{m} factorization");
    }

    #[inline]
    pub fn dim(&self) -> usize {
        self.m
    }

    /// The row-major m×m lower factor (upper part zero) — exposed so
    /// the distributed solver can be seeded from a host-side factor
    /// and so the bit-identity tests can compare factors exactly.
    pub fn lower(&self) -> &[f64] {
        &self.l
    }

    /// Rebuild a solver from its raw parts (the snapshot/restore path
    /// of [`crate::approx::stream`]): the factor is stored verbatim, so
    /// a round-tripped solver is bitwise the one that was saved —
    /// nothing is re-factored.
    pub fn from_raw(l: Vec<f64>, m: usize, ridge: f64) -> SpdSolver {
        assert_eq!(l.len(), m * m, "SpdSolver::from_raw: factor must be m*m");
        assert!(m >= 1);
        SpdSolver { l, m, ridge }
    }

    /// Solve `(W + λI) x = rhs` via forward/back substitution.
    pub fn solve(&self, rhs: &[f64]) -> Vec<f64> {
        let m = self.m;
        assert_eq!(rhs.len(), m);
        // Forward: L y = rhs.
        let mut y = vec![0.0f64; m];
        for i in 0..m {
            let mut s = rhs[i];
            for j in 0..i {
                s -= self.l[i * m + j] * y[j];
            }
            y[i] = s / self.l[i * m + i];
        }
        // Backward: Lᵀ x = y.
        let mut x = vec![0.0f64; m];
        for i in (0..m).rev() {
            let mut s = y[i];
            for j in i + 1..m {
                s -= self.l[j * m + i] * x[j];
            }
            x[i] = s / self.l[i * m + i];
        }
        x
    }
}

/// One diagonal rank's share of the block-cyclic W: for each owned
/// panel (ascending panel index) the full m-row columns, column-major
/// f32 — exactly what [`crate::gemm::gemm_15d_landmark_gram`] hands
/// back in block-cyclic mode, and what [`DistSpdSolver`] factors.
#[derive(Debug, Clone)]
pub struct WPanels {
    pub bc: BlockCyclic,
    /// This rank's index in the diagonal group.
    pub my_idx: usize,
    /// Per owned panel (ascending): column-major m×width f32 block.
    pub cols: Vec<Vec<f32>>,
}

impl WPanels {
    /// Slice a host-resident full W into the panels diagonal-group
    /// index `my_idx` owns. Since the distributed stream-init landed,
    /// production paths build panels through the Gram pipeline's
    /// symmetry redistribution; this remains the test-oracle
    /// construction (and the seed for [`DistSpdSolver::from_host`]).
    pub fn from_full(w: &DenseMatrix, bc: BlockCyclic, my_idx: usize) -> WPanels {
        let m = bc.m();
        assert_eq!(w.rows(), m);
        assert_eq!(w.cols(), m);
        let mut cols = Vec::new();
        for t in bc.owned_panels(my_idx) {
            let (lo, hi) = bc.panel_bounds(t);
            let mut block = Vec::with_capacity(m * (hi - lo));
            for c in lo..hi {
                for u in 0..m {
                    block.push(w.get(u, c));
                }
            }
            cols.push(block);
        }
        WPanels { bc, my_idx, cols }
    }

    /// W's diagonal entries within this rank's panels, as
    /// (global column, value) in ascending column order per panel.
    fn local_diag(&self) -> Vec<f32> {
        let m = self.bc.m();
        let mut out = Vec::new();
        for (pi, &t) in self.bc.owned_panels(self.my_idx).iter().enumerate() {
            let (lo, hi) = self.bc.panel_bounds(t);
            for lc in 0..hi - lo {
                out.push(self.cols[pi][lc * m + (lo + lc)]);
            }
        }
        out
    }
}

/// The W state a 1.5D-landmark diagonal rank carries out of the Gram
/// pipeline: the full matrix (replicated mode) or its block-cyclic
/// panels (distributed mode). Off-diagonal ranks carry neither.
#[derive(Debug, Clone)]
pub enum DiagW {
    Full(DenseMatrix),
    Panels(WPanels),
}

/// Reassemble per-rank panel-ordered payloads (each rank's buffer
/// walks its owned panels ascending, `per_col` values per column) into
/// a flat column-ascending vector of length `m·per_col`.
fn unpack_panel_allgather<T: Copy + Default>(
    bc: &BlockCyclic,
    parts: &[Vec<T>],
    per_col: usize,
) -> Vec<T> {
    let m = bc.m();
    let mut out = vec![T::default(); m * per_col];
    for (idx, buf) in parts.iter().enumerate() {
        let mut cursor = 0usize;
        for t in bc.owned_panels(idx) {
            let (lo, hi) = bc.panel_bounds(t);
            for c in lo..hi {
                out[c * per_col..(c + 1) * per_col]
                    .copy_from_slice(&buf[cursor..cursor + per_col]);
                cursor += per_col;
            }
        }
        debug_assert_eq!(cursor, buf.len());
    }
    out
}

/// Column offsets of a panel's packed lower storage: column `lo + lc`
/// (rows `c..m`) starts at `offs[lc]`.
fn lower_offsets(m: usize, lo: usize, hi: usize) -> Vec<usize> {
    let mut offs = Vec::with_capacity(hi - lo);
    let mut cur = 0usize;
    for c in lo..hi {
        offs.push(cur);
        cur += m - c;
    }
    offs
}

/// The block-cyclic distributed counterpart of [`SpdSolver`]: the
/// Cholesky factor of `W + λI` spread as column panels over the 1.5D
/// grid's diagonal group, with pipelined forward/back substitution.
/// Bit-identical to the replicated solver (see the module docs).
#[derive(Debug, Clone)]
pub struct DistSpdSolver {
    bc: BlockCyclic,
    my_idx: usize,
    /// Per owned panel (ascending): the factored columns' lower parts,
    /// column `c` stored as `l[c..m][c]`, concatenated in column order.
    lower: Vec<Vec<f64>>,
    /// The original W panels (retained for the center norms
    /// c_a = α_aᵀWα_a, which the ridge-free W defines).
    panels: WPanels,
    /// The ridge that made the factorization succeed (identical to the
    /// scalar solver's on the same W).
    pub ridge: f64,
}

impl DistSpdSolver {
    #[inline]
    pub fn dim(&self) -> usize {
        self.bc.m()
    }

    #[inline]
    pub fn block_cyclic(&self) -> &BlockCyclic {
        &self.bc
    }

    /// This rank's factored lower columns (tests compare them bitwise
    /// against the scalar factor).
    pub fn lower_panels(&self) -> &[Vec<f64>] {
        &self.lower
    }

    /// This solver's index in the diagonal group.
    #[inline]
    pub fn my_idx(&self) -> usize {
        self.my_idx
    }

    /// The retained W panels (the snapshot/restore path serializes
    /// them alongside the factor).
    pub fn w_panels(&self) -> &WPanels {
        &self.panels
    }

    /// Rebuild a distributed solver from its raw parts (the
    /// snapshot/restore path of [`crate::approx::stream`]): panels and
    /// factor are stored verbatim — nothing is re-factored, so a
    /// round-tripped solver is bitwise the one that was saved.
    pub fn from_raw(
        bc: BlockCyclic,
        my_idx: usize,
        lower: Vec<Vec<f64>>,
        panels: WPanels,
        ridge: f64,
    ) -> DistSpdSolver {
        assert_eq!(panels.bc, bc, "from_raw: panel deal disagrees with the solver's");
        assert_eq!(panels.my_idx, my_idx, "from_raw: panel ownership disagrees");
        let owned = bc.owned_panels(my_idx);
        assert_eq!(lower.len(), owned.len(), "from_raw: one factor block per owned panel");
        assert_eq!(panels.cols.len(), owned.len(), "from_raw: one W block per owned panel");
        let m = bc.m();
        for (bi, &t) in owned.iter().enumerate() {
            let (lo, hi) = bc.panel_bounds(t);
            assert_eq!(lower[bi].len(), lower_len(m, lo, hi), "from_raw: packed factor size");
            assert_eq!(panels.cols[bi].len(), m * (hi - lo), "from_raw: panel block size");
        }
        DistSpdSolver { bc, my_idx, lower, panels, ridge }
    }

    /// The packed lower factor column `c` (rows `c..m`). Panics unless
    /// this solver owns `c`'s panel — the driver-side panel-set solve
    /// below routes each column to its owner.
    fn factor_col(&self, c: usize) -> &[f64] {
        let t = self.bc.panel_of(c);
        assert_eq!(self.bc.owner(t), self.my_idx, "factor_col: column {c} not owned");
        let (lo, hi) = self.bc.panel_bounds(t);
        let offs = lower_offsets(self.bc.m(), lo, hi);
        let start = offs[c - lo];
        &self.lower[self.bc.panel_index(t)][start..start + (self.bc.m() - c)]
    }

    /// The stored W column `c` (full m rows, f32). Panics unless this
    /// solver owns `c`'s panel.
    fn w_col(&self, c: usize) -> &[f32] {
        let t = self.bc.panel_of(c);
        assert_eq!(self.bc.owner(t), self.my_idx, "w_col: column {c} not owned");
        let (lo, _) = self.bc.panel_bounds(t);
        let m = self.bc.m();
        &self.panels.cols[self.bc.panel_index(t)][(c - lo) * m..(c - lo + 1) * m]
    }

    /// Factor the distributed W **collectively over the diagonal
    /// group**: every diagonal rank calls with its own panels. Per
    /// panel: the owner factors it (all updates from earlier panels
    /// already applied), broadcasts the factored columns, and every
    /// rank applies the trailing update to its later panels — the
    /// broadcast panel is the only transient, so peak W state stays at
    /// ~m²/q + one panel. The escalating ridge restarts are collective
    /// (the failure flag rides the panel broadcast), so every rank
    /// lands on the same ridge as the scalar solver would.
    pub fn factor_dist(comm: &Comm, diag: &Group, panels: WPanels) -> DistSpdSolver {
        let bc = panels.bc;
        let m = bc.m();
        let my_idx = diag
            .index_of(comm.rank())
            .expect("factor_dist: caller must be in the diagonal group");
        assert_eq!(my_idx, panels.my_idx, "panel ownership disagrees with group index");
        assert_eq!(diag.size(), bc.q(), "diagonal group size must match the panel deal");

        // Global diagonal of W (ascending), so the trace — and with it
        // the ridge schedule — is computed in exactly the scalar order.
        let diag_parts = comm.allgather(diag, panels.local_diag());
        let w_diag = unpack_panel_allgather(&bc, &diag_parts, 1);
        let trace: f64 = w_diag.iter().map(|&v| v as f64).sum();
        let base = (trace / m as f64).abs().max(1e-12);
        let mut ridge = 1e-8 * base;
        for _ in 0..24 {
            if let Some(lower) = try_cholesky_dist(comm, diag, &panels, ridge) {
                return DistSpdSolver { bc, my_idx, lower, panels, ridge };
            }
            ridge *= 10.0;
        }
        panic!("DistSpdSolver: no ridge stabilized the {m}x{m} factorization");
    }

    /// Build the distributed solver from a host-side replicated factor
    /// ([`SpdSolver::factor`], bit-identical to [`Self::factor_dist`]):
    /// each diagonal index receives only its panel slices. The
    /// streaming driver no longer needs this — stream-init factors W
    /// collectively on the first batch's diagonal group — so it
    /// survives as the bit-identity **test oracle** relating the
    /// scalar and distributed factors (`from_host_matches_factor_dist`)
    /// and as a migration path for host-resident callers.
    pub fn from_host(
        solver: &SpdSolver,
        w: &DenseMatrix,
        bc: BlockCyclic,
        my_idx: usize,
    ) -> DistSpdSolver {
        let m = bc.m();
        assert_eq!(solver.dim(), m);
        let panels = WPanels::from_full(w, bc, my_idx);
        let mut lower = Vec::new();
        let mut total = 0usize;
        for t in bc.owned_panels(my_idx) {
            let (lo, hi) = bc.panel_bounds(t);
            let mut block = Vec::with_capacity(lower_len(m, lo, hi));
            for c in lo..hi {
                for i in c..m {
                    block.push(solver.l[i * m + c]);
                }
            }
            total += block.len();
            lower.push(block);
        }
        // The packed factor is exactly the layout's accounted size.
        debug_assert_eq!(total as u64 * 8, bc.factor_bytes(my_idx));
        DistSpdSolver { bc, my_idx, lower, panels, ridge: solver.ridge }
    }

    /// The distributed counterpart of the replicated
    /// `solve_alpha_weighted`: solve the k ridge systems against the
    /// block-cyclic factor and return the full α (k×m f64) plus center
    /// norms on **every** diagonal rank — bit-identical to the
    /// replicated solve on the same inputs.
    ///
    /// Collective over the diagonal group. Schedule per call:
    /// a forward pipeline over panels (each owner finalizes its
    /// columns' y values and applies their updates to all later rows
    /// before passing the token on), the mirrored backward pipeline, a
    /// broadcast of the finished α from the first panel's owner, and an
    /// allgather of the per-column center-norm terms (summed in
    /// ascending column order on every rank — the scalar accumulation
    /// order).
    ///
    /// **Active-set pipelining:** the token is restricted to clusters
    /// with nonzero weight and to the *live row range* of each sweep —
    /// the forward token entering panel t carries only the
    /// not-yet-final rows `[lo_t, m)`, the backward token only the
    /// finalized rows `[hi_t, m)`, and each rank's local buffer keeps
    /// the rows the token no longer carries (exactly what the mirrored
    /// sweep reads back later). Rows of zero-weight clusters are
    /// exactly zero on the scalar path, so never shipping them is
    /// algebraically free: the f64 operation sequence for every live
    /// element is unchanged and the `==` bit-identity pins still hold,
    /// while the solve-phase volume drops by ~2× on the range
    /// restriction alone and further with every inactive cluster
    /// ([`crate::model::analytic::w_blockcyclic_solve_active`]).
    pub fn solve_alpha_weighted(
        &self,
        comm: &Comm,
        diag: &Group,
        b: &[f32],
        weights: &[f64],
        k: usize,
    ) -> (Vec<f64>, Vec<f32>) {
        let m = self.bc.m();
        let n_panels = self.bc.panels();
        debug_assert_eq!(b.len(), k * m);
        debug_assert_eq!(weights.len(), k);
        // The active set is identical on every diagonal rank (weights
        // come out of global reductions), so the shrunken schedule
        // stays collectively consistent without any extra exchange.
        let active: Vec<usize> = (0..k).filter(|&a| weights[a] > 0.0).collect();
        if active.is_empty() {
            // Every α row and center norm is exactly zero on the
            // scalar path too; all ranks take this branch together.
            return (vec![0.0f64; k * m], vec![0.0f32; k]);
        }

        // Normalized right-hand sides (identical on every rank; rows of
        // zero-weight clusters stay exactly zero, like the scalar path).
        let mut z = vec![0.0f64; k * m];
        for &a in &active {
            let inv = 1.0 / weights[a];
            for t in 0..m {
                z[a * m + t] = b[a * m + t] as f64 * inv;
            }
        }

        // Token (de)serialization: rows [r0, m) of every active cluster.
        let pack = |z: &[f64], r0: usize| -> Vec<f64> {
            let mut buf = Vec::with_capacity(active.len() * (m - r0));
            for &a in &active {
                buf.extend_from_slice(&z[a * m + r0..(a + 1) * m]);
            }
            buf
        };
        let unpack = |z: &mut [f64], r0: usize, buf: &[f64]| {
            let w = m - r0;
            debug_assert_eq!(buf.len(), active.len() * w);
            for (ai, &a) in active.iter().enumerate() {
                z[a * m + r0..(a + 1) * m].copy_from_slice(&buf[ai * w..(ai + 1) * w]);
            }
        };

        // Forward pipeline: L y = rhs, panels ascending. The token
        // entering panel t is the shrinking tail [lo_t, m); finalized y
        // values stay on the rank that produced them.
        let tag_f = comm.next_tag(diag);
        for p in 0..n_panels {
            if self.bc.owner(p) != self.my_idx {
                continue;
            }
            let (lo, hi) = self.bc.panel_bounds(p);
            if p > 0 && self.bc.owner(p - 1) != self.my_idx {
                let buf: Vec<f64> =
                    comm.recv(diag.rank_at(self.bc.owner(p - 1)), tag_f.wrapping_add(p as u64));
                unpack(&mut z, lo, &buf);
            }
            let offs = lower_offsets(m, lo, hi);
            let lower = &self.lower[self.bc.panel_index(p)];
            for &a in &active {
                let za = &mut z[a * m..(a + 1) * m];
                for lc in 0..hi - lo {
                    let c = lo + lc;
                    let col = &lower[offs[lc]..offs[lc] + (m - c)];
                    // All t < c already subtracted (earlier panels via
                    // the pipeline, this panel via the loop below), in
                    // ascending t — the scalar order.
                    let y = za[c] / col[0];
                    za[c] = y;
                    for i in c + 1..m {
                        za[i] -= col[i - c] * y;
                    }
                }
            }
            if p + 1 < n_panels && self.bc.owner(p + 1) != self.my_idx {
                let buf = pack(&z, hi);
                let bytes = (buf.len() * 8) as u64;
                comm.send(
                    diag.rank_at(self.bc.owner(p + 1)),
                    tag_f.wrapping_add((p + 1) as u64),
                    buf,
                );
                comm.record_critical(1, bytes);
            }
        }

        // Backward pipeline: Lᵀ x = y, panels descending. The token
        // entering panel t is the grown tail of finalized x values
        // [hi_t, m); each owner's y values for its own columns were
        // kept local by the forward sweep's range restriction.
        let tag_b = comm.next_tag(diag);
        for p in (0..n_panels).rev() {
            if self.bc.owner(p) != self.my_idx {
                continue;
            }
            let (lo, hi) = self.bc.panel_bounds(p);
            if p + 1 < n_panels && self.bc.owner(p + 1) != self.my_idx {
                let buf: Vec<f64> =
                    comm.recv(diag.rank_at(self.bc.owner(p + 1)), tag_b.wrapping_add(p as u64));
                unpack(&mut z, hi, &buf);
            }
            let offs = lower_offsets(m, lo, hi);
            let lower = &self.lower[self.bc.panel_index(p)];
            for &a in &active {
                let za = &mut z[a * m..(a + 1) * m];
                for lc in (0..hi - lo).rev() {
                    let c = lo + lc;
                    let col = &lower[offs[lc]..offs[lc] + (m - c)];
                    let mut s = za[c];
                    // u ascending over the already-final x values —
                    // the scalar back-substitution order.
                    for u in c + 1..m {
                        s -= col[u - c] * za[u];
                    }
                    za[c] = s / col[0];
                }
            }
            if p > 0 && self.bc.owner(p - 1) != self.my_idx {
                let buf = pack(&z, lo);
                let bytes = (buf.len() * 8) as u64;
                comm.send(
                    diag.rank_at(self.bc.owner(p - 1)),
                    tag_b.wrapping_add((p - 1) as u64),
                    buf,
                );
                comm.record_critical(1, bytes);
            }
        }

        // Panel 0's owner (group index 0) now holds the complete α for
        // every active cluster; inactive rows are exactly zero.
        let packed = comm.bcast(diag, 0, (self.my_idx == 0).then(|| pack(&z, 0)));
        let mut alpha = vec![0.0f64; k * m];
        for (ai, &a) in active.iter().enumerate() {
            alpha[a * m..(a + 1) * m].copy_from_slice(&packed[ai * m..(ai + 1) * m]);
        }

        // Center norms c_a = α_aᵀWα_a: the owner of column t computes
        // row_t = Σ_u W[t][u]·α[u] from its stored full column t (W is
        // bitwise symmetric) and the term α[t]·row_t; the terms are
        // allgathered and summed in ascending t on every rank —
        // exactly the scalar accumulation. Inactive clusters' terms are
        // exactly zero on the scalar path and are never computed or
        // shipped here.
        let owned = self.bc.owned_panels(self.my_idx);
        let ka = active.len();
        let mut local_terms: Vec<f64> =
            Vec::with_capacity(ka * self.bc.owned_cols(self.my_idx));
        for (pi, &t_panel) in owned.iter().enumerate() {
            let (lo, hi) = self.bc.panel_bounds(t_panel);
            for lc in 0..hi - lo {
                let c = lo + lc;
                let wcol = &self.panels.cols[pi][lc * m..(lc + 1) * m];
                for &a in &active {
                    let al = &alpha[a * m..(a + 1) * m];
                    let mut row = 0.0f64;
                    for u in 0..m {
                        row += wcol[u] as f64 * al[u];
                    }
                    local_terms.push(al[c] * row);
                }
            }
        }
        let term_parts = comm.allgather(diag, local_terms);
        let terms = unpack_panel_allgather(&self.bc, &term_parts, ka);
        let mut cvec = vec![0.0f32; k];
        for (ai, &a) in active.iter().enumerate() {
            let mut s = 0.0f64;
            for t in 0..m {
                s += terms[t * ka + ai];
            }
            cvec[a] = s as f32;
        }
        (alpha, cvec)
    }
}

/// Driver-side solve over a **complete panel set** (one
/// [`DistSpdSolver`] per diagonal index, ascending): the streaming
/// driver's substitute for the scalar [`SpdSolver`] after the
/// distributed stream-init removed its m²-f64 host factor. Walks the
/// factor and W columns through their owners without ever assembling
/// either matrix, performing **exactly the scalar operation sequence**
/// (row-major j-ascending forward, j-ascending backward against column
/// tails, ascending-u center-norm accumulation over the bitwise-
/// symmetric W columns) — so the output is bit-identical to
/// `solve_alpha_weighted(&SpdSolver::factor(w), ...)` on the same W.
/// Used only for rare driver-side classifies (undersized tails,
/// reservoir refresh re-expression); per-batch solves stay on the
/// collective pipeline.
pub fn host_solve_alpha_weighted_panels(
    solvers: &[DistSpdSolver],
    b: &[f32],
    weights: &[f64],
    k: usize,
) -> (Vec<f64>, Vec<f32>) {
    assert!(!solvers.is_empty(), "panel-set solve needs at least one solver");
    let bc = solvers[0].bc;
    let m = bc.m();
    assert_eq!(solvers.len(), bc.q(), "one solver per diagonal index");
    debug_assert_eq!(b.len(), k * m);
    debug_assert_eq!(weights.len(), k);
    // Per-column views, resolved once: column c lives on the owner of
    // its panel (each solver asserts it holds what it is asked for).
    let lcols: Vec<&[f64]> =
        (0..m).map(|c| solvers[bc.owner(bc.panel_of(c))].factor_col(c)).collect();
    let wcols: Vec<&[f32]> =
        (0..m).map(|c| solvers[bc.owner(bc.panel_of(c))].w_col(c)).collect();

    let mut alpha = vec![0.0f64; k * m];
    for a in 0..k {
        if weights[a] <= 0.0 {
            continue;
        }
        let inv = 1.0 / weights[a];
        let rhs: Vec<f64> = b[a * m..(a + 1) * m].iter().map(|&v| v as f64 * inv).collect();
        // Forward: L y = rhs, the scalar row loop with l[i][j] read as
        // column j's packed tail entry.
        let mut y = vec![0.0f64; m];
        for i in 0..m {
            let mut s = rhs[i];
            for (j, &yj) in y.iter().enumerate().take(i) {
                s -= lcols[j][i - j] * yj;
            }
            y[i] = s / lcols[i][0];
        }
        // Backward: Lᵀ x = y; l[j][i] is column i's entry at row j.
        let mut x = vec![0.0f64; m];
        for i in (0..m).rev() {
            let mut s = y[i];
            for j in i + 1..m {
                s -= lcols[i][j - i] * x[j];
            }
            x[i] = s / lcols[i][0];
        }
        alpha[a * m..(a + 1) * m].copy_from_slice(&x);
    }
    let mut cvec = vec![0.0f32; k];
    for a in 0..k {
        let al = &alpha[a * m..(a + 1) * m];
        let mut s = 0.0f64;
        for t in 0..m {
            // W[t][u] = W[u][t] (bitwise symmetry) = column t, row u;
            // u ascends, the scalar accumulation order.
            let mut row = 0.0f64;
            for (wv, &alu) in wcols[t].iter().zip(al.iter()) {
                row += *wv as f64 * alu;
            }
            s += al[t] * row;
        }
        cvec[a] = s as f32;
    }
    (alpha, cvec)
}

/// One distributed factorization attempt at a fixed ridge: panel
/// factorization + broadcast + ascending-t trailing updates. Returns
/// the owned panels' factored lower columns, or `None` when any pivot
/// fails (every rank agrees — the flag rides the broadcast).
fn try_cholesky_dist(
    comm: &Comm,
    diag: &Group,
    panels: &WPanels,
    ridge: f64,
) -> Option<Vec<Vec<f64>>> {
    let bc = panels.bc;
    let m = bc.m();
    let my_idx = panels.my_idx;
    let owned = bc.owned_panels(my_idx);

    // Working storage: owned columns' lower parts in f64, seeded as
    // (W as f64) + ridge on the diagonal — the scalar initial value.
    let mut work: Vec<Vec<f64>> = owned
        .iter()
        .enumerate()
        .map(|(pi, &t)| {
            let (lo, hi) = bc.panel_bounds(t);
            let mut block = Vec::with_capacity(lower_len(m, lo, hi));
            for lc in 0..hi - lo {
                let c = lo + lc;
                for i in c..m {
                    let mut v = panels.cols[pi][lc * m + i] as f64;
                    if i == c {
                        v += ridge;
                    }
                    block.push(v);
                }
            }
            block
        })
        .collect();

    let mut failed = false;
    for p in 0..bc.panels() {
        let owner = bc.owner(p);
        // Every diagonal rank consumes the broadcast panel — the
        // layout's declared replication group must be the whole group.
        debug_assert_eq!(bc.panel_replication_group(p).len(), diag.size());
        let (lo, hi) = bc.panel_bounds(p);
        let offs = lower_offsets(m, lo, hi);
        let payload = if owner == my_idx && !failed {
            let a = &mut work[bc.panel_index(p)];
            let mut ok = true;
            'cols: for lc in 0..hi - lo {
                let c = lo + lc;
                let s = a[offs[lc]];
                if s <= 0.0 || !s.is_finite() {
                    ok = false;
                    break 'cols;
                }
                let lcc = s.sqrt();
                a[offs[lc]] = lcc;
                for i in c + 1..m {
                    a[offs[lc] + (i - c)] /= lcc;
                }
                // Rank-1 update of the finished column onto the later
                // columns of this panel (ascending t per element —
                // cross-panel updates arrive later via the broadcast).
                for lj in lc + 1..hi - lo {
                    let j = lo + lj;
                    let ljc = a[offs[lc] + (j - c)];
                    for i in j..m {
                        a[offs[lj] + (i - j)] -= a[offs[lc] + (i - c)] * ljc;
                    }
                }
            }
            if ok {
                let mut buf = Vec::with_capacity(1 + a.len());
                buf.push(1.0f64);
                buf.extend_from_slice(a);
                Some(buf)
            } else {
                Some(vec![0.0f64])
            }
        } else if owner == my_idx {
            Some(vec![0.0f64])
        } else {
            None
        };
        let buf = comm.bcast(diag, owner, payload);
        if buf[0] == 0.0 {
            failed = true;
            continue; // keep the collective schedule aligned
        }
        if failed {
            continue;
        }
        // Trailing update: subtract this panel's columns (t ascending)
        // from every later owned panel.
        let lpanel = &buf[1..];
        for t in lo..hi {
            let lt = &lpanel[offs[t - lo]..offs[t - lo] + (m - t)];
            for (pi, &op) in owned.iter().enumerate() {
                if op <= p {
                    continue;
                }
                let (plo, phi) = bc.panel_bounds(op);
                let poffs = lower_offsets(m, plo, phi);
                let a = &mut work[pi];
                for lc in 0..phi - plo {
                    let c = plo + lc;
                    let lct = lt[c - t];
                    for i in c..m {
                        a[poffs[lc] + (i - c)] -= lt[i - t] * lct;
                    }
                }
            }
        }
    }
    if failed {
        None
    } else {
        Some(work)
    }
}

/// Length of a panel's packed lower storage.
fn lower_len(m: usize, lo: usize, hi: usize) -> usize {
    (lo..hi).map(|c| m - c).sum()
}

/// The solver a 1.5D-landmark diagonal rank drives its per-iteration
/// coefficient solve through — replicated or distributed, selected by
/// [`crate::layout::WFactorization`]. Both arms produce bit-identical
/// (α, center-norm) output; only the memory and communication schedules
/// differ.
pub(crate) enum DiagSolver {
    Replicated { solver: SpdSolver, w: DenseMatrix },
    Dist(DistSpdSolver),
}

impl DiagSolver {
    /// Solve the k weighted ridge systems; collective over `diag` in
    /// the distributed arm, purely local in the replicated arm.
    pub fn solve_weighted(
        &self,
        comm: &Comm,
        diag: &Group,
        b: &[f32],
        weights: &[f64],
        k: usize,
    ) -> (Vec<f64>, Vec<f32>) {
        match self {
            DiagSolver::Replicated { solver, w } => {
                super::solve_alpha_weighted(solver, w, b, weights, k)
            }
            DiagSolver::Dist(d) => d.solve_alpha_weighted(comm, diag, b, weights, k),
        }
    }
}

/// Plain lower Cholesky of `w + ridge·I` in f64; `None` on a
/// non-positive or non-finite pivot.
fn try_cholesky(w: &DenseMatrix, ridge: f64) -> Option<Vec<f64>> {
    let m = w.rows();
    let mut l = vec![0.0f64; m * m];
    for i in 0..m {
        for j in 0..=i {
            let mut s = w.get(i, j) as f64;
            if i == j {
                s += ridge;
            }
            for t in 0..j {
                s -= l[i * m + t] * l[j * m + t];
            }
            if i == j {
                if s <= 0.0 || !s.is_finite() {
                    return None;
                }
                l[i * m + i] = s.sqrt();
            } else {
                l[i * m + j] = s / l[j * m + j];
            }
        }
    }
    Some(l)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn solves_well_conditioned_spd() {
        // W = A·Aᵀ + I is SPD; check W x ≈ b after solving.
        let mut rng = Rng::new(1);
        let m = 12;
        let a = DenseMatrix::random(m, m, &mut rng);
        let mut w = crate::dense::ops::matmul_nt(&a, &a);
        for i in 0..m {
            w.set(i, i, w.get(i, i) + 1.0);
        }
        let solver = SpdSolver::factor(&w);
        let b: Vec<f64> = (0..m).map(|i| (i as f64) - 3.0).collect();
        let x = solver.solve(&b);
        for i in 0..m {
            let wx: f64 = (0..m).map(|j| w.get(i, j) as f64 * x[j]).sum();
            assert!((wx - b[i]).abs() < 1e-4, "row {i}: {wx} vs {}", b[i]);
        }
    }

    #[test]
    fn rank_deficient_gets_ridge() {
        // Rank-1 matrix: plain Cholesky fails, ridge must kick in.
        let m = 6;
        let v: Vec<f32> = (0..m).map(|i| (i + 1) as f32).collect();
        let w = DenseMatrix::from_fn(m, m, |i, j| v[i] * v[j]);
        let solver = SpdSolver::factor(&w);
        assert!(solver.ridge > 0.0);
        let x = solver.solve(&vec![1.0; m]);
        assert!(x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn zero_matrix_solvable() {
        let w = DenseMatrix::zeros(4, 4);
        let solver = SpdSolver::factor(&w);
        let x = solver.solve(&[1.0, 2.0, 3.0, 4.0]);
        assert!(x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn deterministic() {
        let mut rng = Rng::new(2);
        let a = DenseMatrix::random(8, 8, &mut rng);
        let w = crate::dense::ops::matmul_nt(&a, &a);
        let s1 = SpdSolver::factor(&w);
        let s2 = SpdSolver::factor(&w);
        assert_eq!(s1.ridge, s2.ridge);
        assert_eq!(s1.solve(&[1.0; 8]), s2.solve(&[1.0; 8]));
    }

    /// Extract the scalar factor's lower columns in the distributed
    /// panel layout, for bitwise comparison.
    fn scalar_panel(solver: &SpdSolver, bc: &BlockCyclic, idx: usize) -> Vec<Vec<f64>> {
        let m = solver.dim();
        bc.owned_panels(idx)
            .iter()
            .map(|&t| {
                let (lo, hi) = bc.panel_bounds(t);
                let mut block = Vec::new();
                for c in lo..hi {
                    for i in c..m {
                        block.push(solver.lower()[i * m + c]);
                    }
                }
                block
            })
            .collect()
    }

    #[test]
    fn dist_factor_bitwise_matches_scalar() {
        use crate::comm::World;
        let mut rng = Rng::new(11);
        let m = 29; // odd, so panels are ragged
        let a = DenseMatrix::random(m, m, &mut rng);
        let mut w = crate::dense::ops::matmul_nt(&a, &a);
        for i in 0..m {
            w.set(i, i, w.get(i, i) + 1.0);
        }
        // Symmetrize bitwise (matmul_nt of A·Aᵀ is already bitwise
        // symmetric, but make the invariant explicit for the test).
        for i in 0..m {
            for j in 0..i {
                let v = w.get(i, j);
                w.set(j, i, v);
            }
        }
        let scalar = SpdSolver::factor(&w);
        for q in [1usize, 2, 3, 4] {
            let bc = BlockCyclic::new(m, q);
            let wref = &w;
            let (results, _) = World::run(q, |comm| {
                let diag = Group::world(q);
                let idx = comm.rank();
                let panels = WPanels::from_full(wref, bc, idx);
                let solver = DistSpdSolver::factor_dist(comm, &diag, panels);
                (solver.ridge, solver.lower_panels().to_vec())
            });
            for (idx, (ridge, lower)) in results.into_iter().enumerate() {
                assert_eq!(ridge, scalar.ridge, "q={q} idx={idx}");
                assert_eq!(lower, scalar_panel(&scalar, &bc, idx), "q={q} idx={idx}");
            }
        }
    }

    #[test]
    fn dist_factor_escalates_ridge_like_scalar() {
        // Rank-1 W: heavily rank-deficient, so the factorization leans
        // on the ridge. Whatever attempt the escalation settles on,
        // the distributed run must land on the scalar ridge and the
        // bitwise-identical factor.
        let m = 9;
        let v: Vec<f32> = (0..m).map(|i| (i + 1) as f32).collect();
        let w = DenseMatrix::from_fn(m, m, |i, j| v[i] * v[j]);
        let scalar = SpdSolver::factor(&w);
        assert!(scalar.ridge > 0.0);
        use crate::comm::World;
        let bc = BlockCyclic::new(m, 3);
        let wref = &w;
        let (results, _) = World::run(3, |comm| {
            let diag = Group::world(3);
            let panels = WPanels::from_full(wref, bc, comm.rank());
            let solver = DistSpdSolver::factor_dist(comm, &diag, panels);
            (solver.ridge, solver.lower_panels().to_vec())
        });
        for (idx, (ridge, lower)) in results.into_iter().enumerate() {
            assert_eq!(ridge, scalar.ridge, "idx={idx}");
            assert_eq!(lower, scalar_panel(&scalar, &bc, idx), "idx={idx}");
        }
    }

    #[test]
    fn dist_solve_bitwise_matches_replicated() {
        use crate::comm::World;
        let mut rng = Rng::new(12);
        let m = 17;
        let k = 4;
        let a = DenseMatrix::random(m, m, &mut rng);
        let mut w = crate::dense::ops::matmul_nt(&a, &a);
        for i in 0..m {
            w.set(i, i, w.get(i, i) + 0.5);
            for j in 0..i {
                let v = w.get(i, j);
                w.set(j, i, v);
            }
        }
        let b: Vec<f32> = (0..k * m).map(|x| ((x * 7 % 13) as f32) - 5.0).collect();
        // One zero-weight cluster: its α row and center norm must stay
        // exactly zero on both paths.
        let weights = vec![3.0f64, 0.0, 1.5, 7.0];
        let scalar = SpdSolver::factor(&w);
        let (want_alpha, want_cvec) =
            super::super::solve_alpha_weighted(&scalar, &w, &b, &weights, k);
        for q in [1usize, 2, 4] {
            let bc = BlockCyclic::with_panel(m, q, 3);
            let (wref, bref, wtref) = (&w, &b, &weights);
            let (results, _) = World::run(q, |comm| {
                let diag = Group::world(q);
                let panels = WPanels::from_full(wref, bc, comm.rank());
                let solver = DistSpdSolver::factor_dist(comm, &diag, panels);
                solver.solve_alpha_weighted(comm, &diag, bref, wtref, k)
            });
            for (idx, (alpha, cvec)) in results.into_iter().enumerate() {
                assert_eq!(alpha, want_alpha, "q={q} idx={idx}");
                assert_eq!(cvec, want_cvec, "q={q} idx={idx}");
            }
        }
    }

    /// The driver-side panel-set solve must be bit-identical to the
    /// replicated scalar solve — it is what classifies tail batches
    /// once the stream no longer holds a host factor.
    #[test]
    fn host_panel_solve_bitwise_matches_replicated() {
        use crate::comm::World;
        let mut rng = Rng::new(14);
        let m = 19; // ragged panels
        let k = 3;
        let a = DenseMatrix::random(m, m, &mut rng);
        let mut w = crate::dense::ops::matmul_nt(&a, &a);
        for i in 0..m {
            w.set(i, i, w.get(i, i) + 0.25);
            for j in 0..i {
                let v = w.get(i, j);
                w.set(j, i, v);
            }
        }
        let b: Vec<f32> = (0..k * m).map(|x| ((x * 5 % 11) as f32) - 4.0).collect();
        let weights = vec![2.0f64, 0.0, 5.5];
        let scalar = SpdSolver::factor(&w);
        let (want_alpha, want_cvec) =
            super::super::solve_alpha_weighted(&scalar, &w, &b, &weights, k);
        for q in [1usize, 2, 3] {
            let bc = BlockCyclic::new(m, q);
            let wref = &w;
            let (solvers, _) = World::run(q, |comm| {
                let diag = Group::world(q);
                let panels = WPanels::from_full(wref, bc, comm.rank());
                DistSpdSolver::factor_dist(comm, &diag, panels)
            });
            let (alpha, cvec) = host_solve_alpha_weighted_panels(&solvers, &b, &weights, k);
            assert_eq!(alpha, want_alpha, "q={q}");
            assert_eq!(cvec, want_cvec, "q={q}");
        }
    }

    /// The active-set restriction must shrink the pipelined token:
    /// with half the clusters at zero weight, the counted solve bytes
    /// sit well below the all-active volume of the same call — while
    /// the output stays bitwise equal to the replicated solve (pinned
    /// above by `dist_solve_bitwise_matches_replicated`).
    #[test]
    fn active_set_solve_moves_fewer_bytes() {
        use crate::comm::World;
        let mut rng = Rng::new(15);
        let m = 24;
        let k = 8;
        let q = 4;
        let a = DenseMatrix::random(m, m, &mut rng);
        let mut w = crate::dense::ops::matmul_nt(&a, &a);
        for i in 0..m {
            w.set(i, i, w.get(i, i) + 1.0);
            for j in 0..i {
                let v = w.get(i, j);
                w.set(j, i, v);
            }
        }
        let b: Vec<f32> = (0..k * m).map(|x| ((x * 3 % 7) as f32) - 2.0).collect();
        let full: Vec<f64> = (1..=k).map(|a| a as f64).collect();
        let mut skewed = full.clone();
        for a in 0..k / 2 {
            skewed[a] = 0.0;
        }
        let bc = BlockCyclic::new(m, q);
        let run = |weights: &[f64]| -> u64 {
            let (wref, bref) = (&w, &b);
            let (_, stats) = World::run(q, |comm| {
                let diag = Group::world(q);
                let panels = WPanels::from_full(wref, bc, comm.rank());
                let solver = DistSpdSolver::factor_dist(comm, &diag, panels);
                comm.set_phase("solve");
                solver.solve_alpha_weighted(comm, &diag, bref, weights, k)
            });
            stats.iter().map(|s| s.get("solve").bytes).sum()
        };
        let full_bytes = run(&full);
        let skewed_bytes = run(&skewed);
        assert!(
            skewed_bytes * 3 <= full_bytes * 2,
            "half-active solve must move well under 2/3 of the all-active bytes \
             (skewed {skewed_bytes} vs full {full_bytes})"
        );
    }

    #[test]
    fn from_host_matches_factor_dist() {
        use crate::comm::World;
        let mut rng = Rng::new(13);
        let m = 12;
        let a = DenseMatrix::random(m, m, &mut rng);
        let mut w = crate::dense::ops::matmul_nt(&a, &a);
        for i in 0..m {
            w.set(i, i, w.get(i, i) + 1.0);
            for j in 0..i {
                let v = w.get(i, j);
                w.set(j, i, v);
            }
        }
        let scalar = SpdSolver::factor(&w);
        let bc = BlockCyclic::new(m, 2);
        let wref = &w;
        let (results, _) = World::run(2, |comm| {
            let diag = Group::world(2);
            let panels = WPanels::from_full(wref, bc, comm.rank());
            let solver = DistSpdSolver::factor_dist(comm, &diag, panels);
            solver.lower_panels().to_vec()
        });
        for (idx, lower) in results.into_iter().enumerate() {
            let host = DistSpdSolver::from_host(&scalar, &w, bc, idx);
            assert_eq!(host.lower_panels(), &lower[..], "idx={idx}");
            assert_eq!(host.ridge, scalar.ridge);
        }
    }
}
