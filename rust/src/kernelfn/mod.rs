//! Kernel functions κ(x, y) applied elementwise to the Gram matrix.
//!
//! K(i,j) = κ(P(i,:), P(j,:)) is computed from the Gram value
//! B(i,j) = ⟨x, y⟩ (plus squared norms for the Gaussian kernel), so the
//! kernel application fuses into the Gram GEMM — the paper's Eq. (2)
//! path, and the same fusion the Pallas L1 kernel performs on-device.

/// Supported kernel functions.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum KernelFn {
    /// κ(x,y) = ⟨x,y⟩ (the paper's analysis default, B = K).
    Linear,
    /// κ(x,y) = (γ⟨x,y⟩ + c)^degree — the paper's benchmark kernel
    /// (γ=1, c=1, degree=2).
    Polynomial { gamma: f32, c: f32, degree: f32 },
    /// κ(x,y) = exp(−γ‖x−y‖²), using ‖x−y‖² = ‖x‖² + ‖y‖² − 2⟨x,y⟩.
    Gaussian { gamma: f32 },
}

impl Default for KernelFn {
    fn default() -> Self {
        KernelFn::paper_polynomial()
    }
}

impl KernelFn {
    pub fn linear() -> Self {
        KernelFn::Linear
    }

    pub fn polynomial(gamma: f32, c: f32, degree: f32) -> Self {
        KernelFn::Polynomial { gamma, c, degree }
    }

    /// The paper's evaluation kernel: (⟨x,y⟩ + 1)².
    pub fn paper_polynomial() -> Self {
        KernelFn::Polynomial { gamma: 1.0, c: 1.0, degree: 2.0 }
    }

    pub fn gaussian(gamma: f32) -> Self {
        KernelFn::Gaussian { gamma }
    }

    /// Whether this kernel needs the squared norms of the two points in
    /// addition to their inner product.
    pub fn needs_norms(&self) -> bool {
        matches!(self, KernelFn::Gaussian { .. })
    }

    /// Apply to a single Gram entry. `dot` = ⟨x,y⟩; `nx`, `ny` = ‖x‖²,
    /// ‖y‖² (ignored unless [`Self::needs_norms`]).
    #[inline]
    pub fn apply(&self, dot: f32, nx: f32, ny: f32) -> f32 {
        match *self {
            KernelFn::Linear => dot,
            KernelFn::Polynomial { gamma, c, degree } => {
                let base = gamma * dot + c;
                if degree == 2.0 {
                    base * base
                } else if degree == 3.0 {
                    base * base * base
                } else {
                    base.powf(degree)
                }
            }
            KernelFn::Gaussian { gamma } => (-gamma * (nx + ny - 2.0 * dot)).exp(),
        }
    }

    /// Apply in place to a Gram tile B (rows i map to `row_norms`,
    /// columns j to `col_norms`).
    pub fn apply_tile(
        &self,
        b: &mut crate::dense::DenseMatrix,
        row_norms: &[f32],
        col_norms: &[f32],
    ) {
        if !self.needs_norms() {
            for v in b.data_mut() {
                *v = self.apply(*v, 0.0, 0.0);
            }
            return;
        }
        assert_eq!(row_norms.len(), b.rows());
        assert_eq!(col_norms.len(), b.cols());
        let cols = b.cols();
        for i in 0..b.rows() {
            let nx = row_norms[i];
            let row = &mut b.data_mut()[i * cols..(i + 1) * cols];
            for (j, v) in row.iter_mut().enumerate() {
                *v = self.apply(*v, nx, col_norms[j]);
            }
        }
    }

    /// Stable identifier used in artifact names (`gram_poly_...`).
    pub fn tag(&self) -> &'static str {
        match self {
            KernelFn::Linear => "linear",
            KernelFn::Polynomial { .. } => "poly",
            KernelFn::Gaussian { .. } => "rbf",
        }
    }

    /// Scalar parameters in a fixed order (for artifact dispatch).
    pub fn params(&self) -> Vec<f32> {
        match *self {
            KernelFn::Linear => vec![],
            KernelFn::Polynomial { gamma, c, degree } => vec![gamma, c, degree],
            KernelFn::Gaussian { gamma } => vec![gamma],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dense::DenseMatrix;

    #[test]
    fn linear_is_identity() {
        assert_eq!(KernelFn::linear().apply(3.5, 9.0, 9.0), 3.5);
    }

    #[test]
    fn paper_polynomial_values() {
        let k = KernelFn::paper_polynomial();
        // (1*2 + 1)^2 = 9
        assert_eq!(k.apply(2.0, 0.0, 0.0), 9.0);
        assert_eq!(k.apply(0.0, 0.0, 0.0), 1.0);
        assert_eq!(k.apply(-1.0, 0.0, 0.0), 0.0);
    }

    #[test]
    fn cubic_and_fractional_degrees() {
        let k3 = KernelFn::polynomial(1.0, 0.0, 3.0);
        assert_eq!(k3.apply(2.0, 0.0, 0.0), 8.0);
        let k15 = KernelFn::polynomial(1.0, 0.0, 1.5);
        assert!((k15.apply(4.0, 0.0, 0.0) - 8.0).abs() < 1e-6);
    }

    #[test]
    fn gaussian_from_gram() {
        let k = KernelFn::gaussian(0.5);
        // x = (1,0), y = (0,1): dot=0, norms=1 -> exp(-0.5 * 2) = e^-1.
        let v = k.apply(0.0, 1.0, 1.0);
        assert!((v - (-1.0f32).exp()).abs() < 1e-6);
        // Same point: distance 0 -> 1.
        assert_eq!(k.apply(1.0, 1.0, 1.0), 1.0);
    }

    #[test]
    fn apply_tile_poly() {
        let mut b = DenseMatrix::from_vec(2, 2, vec![0.0, 1.0, 2.0, 3.0]);
        KernelFn::paper_polynomial().apply_tile(&mut b, &[0.0; 2], &[0.0; 2]);
        assert_eq!(b.data(), &[1.0, 4.0, 9.0, 16.0]);
    }

    #[test]
    fn apply_tile_gaussian_uses_norms() {
        let mut b = DenseMatrix::from_vec(1, 2, vec![0.0, 1.0]);
        KernelFn::gaussian(1.0).apply_tile(&mut b, &[1.0], &[1.0, 1.0]);
        assert!((b.get(0, 0) - (-2.0f32).exp()).abs() < 1e-6);
        assert!((b.get(0, 1) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn symmetry_property() {
        // κ(x,y) == κ(y,x) for all kernel types on random Gram entries.
        use crate::util::rng::Rng;
        let mut rng = Rng::new(3);
        for kf in [KernelFn::linear(), KernelFn::paper_polynomial(), KernelFn::gaussian(0.7)] {
            for _ in 0..50 {
                let dot = rng.next_f32();
                let nx = rng.next_f32() + 1.0;
                let ny = rng.next_f32() + 1.0;
                assert_eq!(kf.apply(dot, nx, ny), kf.apply(dot, ny, nx));
            }
        }
    }
}
