//! The assignment matrix V in its minimal structured form.
//!
//! V(i,j) = 1/|L_i| if point j belongs to cluster i, else 0 — exactly
//! one nonzero per column. A local partition over a contiguous block of
//! points (columns of V) is therefore fully described by the per-point
//! cluster assignment; values are recovered from the global cluster
//! sizes (allreduced each iteration). This is the paper's wire format:
//! "communication of V partitions involves only their local row
//! indices" (§V).

use super::csc::CscMatrix;

/// Local partition of V covering points
/// `[col_offset, col_offset + assign.len())`.
#[derive(Debug, Clone, PartialEq)]
pub struct VPartition {
    /// Number of clusters (rows of V).
    pub k: usize,
    /// Global index of the first local point.
    pub col_offset: usize,
    /// Cluster assignment of each local point (the CSC row indices).
    pub assign: Vec<u32>,
}

impl VPartition {
    /// Round-robin initialization (the paper's §V strategy): global
    /// point j starts in cluster j mod k.
    pub fn round_robin(k: usize, col_offset: usize, n_local: usize) -> Self {
        let assign = (0..n_local).map(|j| ((col_offset + j) % k) as u32).collect();
        VPartition { k, col_offset, assign }
    }

    /// From an explicit assignment vector.
    pub fn from_assign(k: usize, col_offset: usize, assign: Vec<u32>) -> Self {
        let v = VPartition { k, col_offset, assign };
        v.validate();
        v
    }

    /// Panics if any assignment is out of range — the one-nonzero-per-
    /// column invariant is structural (every point has exactly one
    /// cluster by construction).
    pub fn validate(&self) {
        assert!(
            self.assign.iter().all(|&a| (a as usize) < self.k),
            "assignment out of range"
        );
    }

    #[inline]
    pub fn n_local(&self) -> usize {
        self.assign.len()
    }

    /// Local contribution to the global cluster sizes.
    pub fn local_sizes(&self) -> Vec<u64> {
        let mut sizes = vec![0u64; self.k];
        for &a in &self.assign {
            sizes[a as usize] += 1;
        }
        sizes
    }

    /// Explicit CSC form given the global cluster sizes (tests and the
    /// general-SpMM cross-check). Column j has the single entry
    /// (assign[j], 1/|L_assign[j]|).
    pub fn to_csc(&self, global_sizes: &[u64]) -> CscMatrix {
        assert_eq!(global_sizes.len(), self.k);
        let n = self.n_local();
        let colptr: Vec<usize> = (0..=n).collect();
        let rowidx = self.assign.clone();
        let values: Vec<f32> = self
            .assign
            .iter()
            .map(|&a| {
                let s = global_sizes[a as usize];
                assert!(s > 0, "cluster {a} is empty but has members assigned");
                1.0 / s as f32
            })
            .collect();
        CscMatrix::new(self.k, n, colptr, rowidx, values)
    }

    /// Inverse cluster sizes as f32 (the V values per row), with empty
    /// clusters mapped to 0 so they contribute nothing.
    pub fn inv_sizes(global_sizes: &[u64]) -> Vec<f32> {
        global_sizes.iter().map(|&s| if s == 0 { 0.0 } else { 1.0 / s as f32 }).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_uses_global_index() {
        let v = VPartition::round_robin(3, 4, 5);
        // global points 4..9 -> clusters 1,2,0,1,2
        assert_eq!(v.assign, vec![1, 2, 0, 1, 2]);
    }

    #[test]
    fn local_sizes_count() {
        let v = VPartition::from_assign(3, 0, vec![0, 1, 1, 2, 1]);
        assert_eq!(v.local_sizes(), vec![1, 3, 1]);
    }

    #[test]
    fn csc_has_one_nnz_per_column() {
        let v = VPartition::round_robin(4, 0, 10);
        let sizes = vec![3u64, 3, 2, 2];
        let csc = v.to_csc(&sizes);
        assert_eq!(csc.nnz(), 10);
        for j in 0..10 {
            assert_eq!(csc.col(j).count(), 1);
            let (r, val) = csc.col(j).next().unwrap();
            assert_eq!(r, v.assign[j]);
            assert!((val - 1.0 / sizes[r as usize] as f32).abs() < 1e-7);
        }
    }

    #[test]
    fn inv_sizes_handles_empty() {
        let inv = VPartition::inv_sizes(&[2, 0, 4]);
        assert_eq!(inv, vec![0.5, 0.0, 0.25]);
    }

    #[test]
    #[should_panic]
    fn out_of_range_assignment_rejected() {
        let _ = VPartition::from_assign(2, 0, vec![0, 2]);
    }
}
