//! Simulated multi-rank communication fabric with exact accounting.
//!
//! The paper's contribution is the *communication structure* of four
//! distributed Kernel K-means algorithms. This module provides the
//! substrate those algorithms run on in this reproduction:
//!
//! * [`World`] spawns P ranks as OS threads and gives each a [`Comm`]
//!   handle over a shared mailbox fabric ([`fabric`]).
//! * [`collectives`] implements the MPI collectives the paper uses
//!   (Allgather(v), Allreduce, Reduce, Reduce_scatter_block, Bcast,
//!   Gather, Alltoallv, Barrier) with textbook algorithms whose
//!   message/word counts match the α-β analysis in the paper's §IV.
//! * Every collective records **exact** per-phase communication counts
//!   (total messages/bytes sent by this rank) *and* the critical-path
//!   α-β terms (rounds, bytes on the critical path) into [`CommStats`],
//!   from which Table I and the runtime-breakdown figures are produced.
//! * [`grid::Grid2D`] arranges ranks column-major (required by the 1.5D
//!   reduce-scatter layout, paper §V.C) and derives row/column groups.
//! * [`fault`] injects deterministic, seeded failures ([`FaultPlan`]:
//!   rank crashes at the Nth collective, message drops, bounded delays,
//!   payload corruption); [`World::try_run`] and the `try_*` collective
//!   variants surface every failure as a typed [`CommError`] within a
//!   bounded recv deadline — never a hang — while the infallible APIs
//!   delegate with [`FaultPlan::none`] and stay bitwise unchanged.
//!
//! Ranks execute real numerics concurrently; the fabric moves real data,
//! so distributed results are testable against single-rank oracles.

pub mod fabric;
pub mod collectives;
pub mod fault;
pub mod grid;
pub mod stats;

pub use fabric::{Comm, CommFailure, World};
pub use fault::{CommError, Fault, FaultKind, FaultPlan};
pub use grid::Grid2D;
pub use stats::{CommStats, FaultCounters, PhaseStats};

/// An ordered set of global ranks forming a communication group
/// (world, a grid row, a grid column, ...). All collective operations
/// are defined over a `Group`; members must call the same sequence of
/// collectives on equal groups.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Group {
    ranks: Vec<usize>,
    /// Stable identifier mixed into message tags so collectives on
    /// different groups never cross-match.
    id: u64,
}

impl Group {
    pub fn new(ranks: Vec<usize>) -> Self {
        assert!(!ranks.is_empty(), "empty group");
        let mut sorted = ranks.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), ranks.len(), "duplicate ranks in group");
        let id = fnv1a(&ranks);
        Group { ranks, id }
    }

    pub fn world(p: usize) -> Self {
        Group::new((0..p).collect())
    }

    #[inline]
    pub fn size(&self) -> usize {
        self.ranks.len()
    }

    #[inline]
    pub fn ranks(&self) -> &[usize] {
        &self.ranks
    }

    #[inline]
    pub fn rank_at(&self, idx: usize) -> usize {
        self.ranks[idx]
    }

    /// Index of a global rank within this group.
    #[inline]
    pub fn index_of(&self, global_rank: usize) -> Option<usize> {
        self.ranks.iter().position(|&r| r == global_rank)
    }

    #[inline]
    pub fn id(&self) -> u64 {
        self.id
    }
}

fn fnv1a(ranks: &[usize]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &r in ranks {
        for b in (r as u64).to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn group_indexing() {
        let g = Group::new(vec![3, 1, 7]);
        assert_eq!(g.size(), 3);
        assert_eq!(g.index_of(1), Some(1));
        assert_eq!(g.index_of(7), Some(2));
        assert_eq!(g.index_of(0), None);
        assert_eq!(g.rank_at(0), 3);
    }

    #[test]
    fn group_ids_differ() {
        let a = Group::new(vec![0, 1, 2, 3]);
        let b = Group::new(vec![0, 1, 2]);
        let c = Group::new(vec![1, 0, 2, 3]); // different order => different id
        assert_ne!(a.id(), b.id());
        assert_ne!(a.id(), c.id());
    }

    #[test]
    #[should_panic]
    fn duplicate_ranks_rejected() {
        let _ = Group::new(vec![0, 1, 1]);
    }
}
