//! Integration tests: every distributed algorithm vs the single-rank
//! oracle, across rank counts, kernels, and datasets; plus the
//! end-to-end feasibility (OOM) behaviour and PJRT-backed fits.

use vivaldi::config::Scale;
use vivaldi::data::{datasets::PaperDataset, synth};
use vivaldi::kernelfn::KernelFn;
use vivaldi::kkmeans::{self, oracle, Algo, FitConfig};
use vivaldi::quality;
use vivaldi::sliding_window::{sliding_window_fit, SwConfig};
use vivaldi::VivaldiError;

fn cfg(k: usize, kernel: KernelFn) -> FitConfig {
    FitConfig { k, max_iters: 40, kernel, converge_on_stable: true, mem: None }
}

/// All four algorithms must reach the oracle's fixed point on
/// well-separated data, at every compatible rank count.
#[test]
fn all_algorithms_match_oracle() {
    let ds = synth::gaussian_blobs(144, 5, 4, 4.5, 101);
    let kernel = KernelFn::paper_polynomial();
    let want = oracle::reference_fit(&ds.points, 4, &kernel, 40);
    assert!(want.converged);
    for algo in Algo::ALL {
        let ps: &[usize] = if algo == Algo::OneD { &[1, 2, 3, 4, 6, 9] } else { &[1, 4, 9, 16] };
        for &p in ps {
            let out = kkmeans::fit(algo, p, &ds.points, &cfg(4, kernel)).unwrap();
            assert_eq!(
                out.assignments,
                want.assignments,
                "algo={} p={p}",
                algo.name()
            );
            assert_eq!(out.iterations, want.iterations, "algo={} p={p}", algo.name());
        }
    }
}

/// Gaussian kernel path end-to-end (norms through SUMMA rows/cols).
#[test]
fn gaussian_kernel_all_algorithms() {
    let ds = synth::concentric_rings(128, 2, 103);
    let kernel = KernelFn::gaussian(2.0);
    let want = oracle::reference_fit(&ds.points, 2, &kernel, 40);
    for algo in Algo::ALL {
        let out = kkmeans::fit(algo, 4, &ds.points, &cfg(2, kernel)).unwrap();
        assert_eq!(out.assignments, want.assignments, "algo={}", algo.name());
        let nmi = quality::nmi(&out.assignments, &ds.labels, 2);
        assert!(nmi > 0.9, "algo={} nmi={nmi}", algo.name());
    }
}

/// The sliding-window baseline reaches the same fixed point as the
/// distributed algorithms (same math, different schedule).
#[test]
fn sliding_window_agrees_with_distributed() {
    let ds = synth::gaussian_blobs(96, 4, 3, 4.0, 105);
    let kernel = KernelFn::paper_polynomial();
    let dist = kkmeans::fit(Algo::OneFiveD, 4, &ds.points, &cfg(3, kernel)).unwrap();
    let be = vivaldi::backend::NativeBackend::new();
    let sw = sliding_window_fit(
        &ds.points,
        &SwConfig { k: 3, max_iters: 40, kernel, block: 17, converge_on_stable: true },
        &be,
    );
    assert_eq!(sw.assignments, dist.assignments);
}

/// Cross-algorithm quality wall: every exact algorithm (1D, H-1D, 2D,
/// 1.5D) must reach the single-rank oracle's NMI on the concentric
/// rings — the paper's motivating non-linearly-separable case — at
/// p ∈ {1, 4, 9}. Pinned against the *oracle's* score (the algorithms
/// provably share its fixed point), so a layout refactor that silently
/// degrades exact-path quality fails here even if it still "clusters".
#[test]
fn exact_quality_wall_on_rings() {
    let ds = synth::concentric_rings(180, 3, 117);
    let kernel = KernelFn::gaussian(2.0);
    let want = oracle::reference_fit(&ds.points, 3, &kernel, 40);
    let oracle_nmi = quality::nmi(&want.assignments, &ds.labels, 3);
    assert!(
        oracle_nmi >= 0.6,
        "the oracle itself must meaningfully separate the rings: nmi={oracle_nmi}"
    );
    for algo in Algo::ALL {
        // All three counts are valid for every algorithm: squares for
        // the grid family, and √9 = 3 ≤ k = 3 for 2D's MINLOC update.
        for &p in &[1usize, 4, 9] {
            let out = kkmeans::fit(algo, p, &ds.points, &cfg(3, kernel)).unwrap();
            let score = quality::nmi(&out.assignments, &ds.labels, 3);
            assert!(
                score + 1e-9 >= oracle_nmi,
                "algo={} p={p}: nmi {score} fell below the oracle's {oracle_nmi}",
                algo.name()
            );
        }
    }
}

/// Uneven divisions: n not divisible by P or by the grid — remainder
/// handling on every path.
#[test]
fn remainder_shapes() {
    let ds = synth::gaussian_blobs(101, 3, 3, 4.0, 107);
    let kernel = KernelFn::linear();
    let want = oracle::reference_fit(&ds.points, 3, &kernel, 30);
    for algo in Algo::ALL {
        let p = if algo == Algo::OneD { 7 } else { 9 };
        let out = kkmeans::fit(algo, p, &ds.points, &cfg(3, kernel)).unwrap();
        assert_eq!(out.assignments, want.assignments, "algo={}", algo.name());
    }
}

/// The paper's weak-scaling feasibility pattern (§VI.B) at our scale:
/// 1D OOMs on the high-d dataset past G=4; H-1D OOMs past G=16; 1.5D
/// and 2D never do.
#[test]
fn feasibility_pattern_matches_paper() {
    let scale = Scale { iters: 2, ..Scale::quick() };
    let machine = vivaldi::model::MachineModel::perlmutter();
    let mem = scale.mem_model_weak(PaperDataset::KddLike);
    let run = |algo, g: usize| {
        vivaldi::bench::run_once(
            algo,
            PaperDataset::KddLike,
            g,
            4,
            scale.weak_n(g),
            &scale,
            &machine,
            Some(mem),
        )
        .oom
    };
    assert!(!run(Algo::OneD, 4), "1D fits at G=4");
    assert!(run(Algo::OneD, 16), "1D OOMs at G=16 (d=10000-equivalent)");
    assert!(!run(Algo::HybridOneD, 16), "H-1D fits at G=16");
    assert!(run(Algo::HybridOneD, 64), "H-1D OOMs at G=64");
    assert!(!run(Algo::OneFiveD, 64), "1.5D fits at G=64");
    assert!(!run(Algo::TwoD, 16), "2D fits at G=16");
}

/// PJRT-backed distributed fit must agree with the native fit exactly
/// (artifact shapes cover the workload; skipped without artifacts).
#[test]
fn pjrt_fit_matches_native() {
    if !vivaldi::runtime::artifacts_available() {
        eprintln!("skipping: no artifacts");
        return;
    }
    if cfg!(debug_assertions) {
        // The n=4096 workload is sized for release builds (the shapes
        // the AOT manifest ships); debug-mode GEMM would take minutes.
        eprintln!("skipping in debug build (run with --release)");
        return;
    }
    let ds = PaperDataset::Mnist8mLike.generate(4096, Some(64), 20260710);
    let c = FitConfig { k: 16, max_iters: 3, converge_on_stable: false, ..Default::default() };
    let native = kkmeans::fit(Algo::OneFiveD, 4, &ds.points, &c).unwrap();
    let be = vivaldi::runtime::PjrtBackend::from_default_artifacts(1).unwrap();
    let pjrt = kkmeans::fit_with_backend(Algo::OneFiveD, 4, &ds.points, &c, &be).unwrap();
    assert_eq!(native.assignments, pjrt.assignments);
    let (hits, _) = be.counters();
    assert!(hits > 0, "pjrt path must actually execute artifacts");
}

/// Objective decreases monotonically on every algorithm (random data,
/// no separability assumption).
#[test]
fn objective_monotone_all_algorithms() {
    let ds = synth::anisotropic_mixture(120, 6, 4, 109);
    for algo in Algo::ALL {
        let out = kkmeans::fit(algo, 4, &ds.points, &cfg(4, KernelFn::paper_polynomial())).unwrap();
        for w in out.objective_curve.windows(2) {
            assert!(w[1] <= w[0] + 1e-2, "algo={} {w:?}", algo.name());
        }
    }
}

/// Errors surface as typed errors, not hangs or panics.
#[test]
fn error_paths() {
    let ds = synth::gaussian_blobs(32, 2, 2, 3.0, 111);
    // Non-square grid.
    assert!(matches!(
        kkmeans::fit(Algo::OneFiveD, 8, &ds.points, &cfg(2, KernelFn::linear())),
        Err(VivaldiError::InvalidConfig(_))
    ));
    // 2D with √P > k.
    assert!(matches!(
        kkmeans::fit(Algo::TwoD, 16, &ds.points, &cfg(2, KernelFn::linear())),
        Err(VivaldiError::InvalidConfig(_))
    ));
}
