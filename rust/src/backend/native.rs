//! Pure-Rust backend: blocked multithreaded GEMM + structured sparse
//! kernels. Works at every shape; the reference the PJRT backend falls
//! back to and is validated against.
//!
//! Every threaded kernel here parallelizes over **output rows** (or,
//! for the cluster-sum reduction, output **columns**): each output
//! element is produced by exactly one worker with a fixed inner block
//! order, so the f32 op sequence per element — and therefore the bits —
//! is invariant in the thread count. `NativeBackend::scalar()` (one
//! pinned worker) and `NativeBackend::threaded(t)` at any `t` return
//! identical results; `rust/tests/backend.rs` pins this with exact `==`
//! through whole fits.

use super::ComputeBackend;
use crate::dense::{matrix::DenseMatrix, ops};
use crate::kernelfn::KernelFn;
use crate::sparse;
use crate::sparse::CsrMatrix;
use crate::util::par::{par_ranges_with, SendPtr};

/// Row-block floor for the gram/expand GEMMs (matches `dense::ops`).
const PAR_MIN_ROWS: usize = 8;
/// Column-split floor for the cluster-sum reduction.
const PAR_MIN_COLS: usize = 8;
/// Row floor for the cheap elementwise kernels (mask / argmin / κ).
const PAR_MIN_ELEM_ROWS: usize = 256;
/// Cache block over the inner (reduction) dimension.
const BLOCK_K: usize = 256;
/// Cache block over B's rows in the gram panel loop.
const BLOCK_J: usize = 64;

/// The native (pure Rust) compute backend.
///
/// `threads == 0` means "use the global default"
/// (`VIVALDI_THREADS`, else the available parallelism); `threads == 1`
/// pins the exact sequential op order.
#[derive(Debug, Default, Clone)]
pub struct NativeBackend {
    threads: usize,
}

impl NativeBackend {
    /// Global-default thread count (the historical behavior).
    pub fn new() -> Self {
        NativeBackend { threads: 0 }
    }

    /// One pinned worker: the sequential reference every threaded run
    /// must match bit-for-bit.
    pub fn scalar() -> Self {
        NativeBackend { threads: 1 }
    }

    /// An explicit worker-thread cap (0 = global default).
    pub fn threaded(threads: usize) -> Self {
        NativeBackend { threads }
    }

    /// The configured cap (0 = global default).
    pub fn thread_cap(&self) -> usize {
        self.threads
    }
}

/// One cache block of a sparse·dense dot, replaying `ops::dot`'s
/// 8-lane fold on the stored entries only.
///
/// `ops::dot` over a `len`-long block routes position `off` to lane
/// `off & 7` while `off < (len/8)*8` and to a sequential tail after,
/// then combines `((s0+s1)+(s2+s3)) + ((s4+s5)+(s6+s7)) + tail`. Every
/// partial sum starts at +0.0 and an f32 partial sum seeded +0.0 can
/// never become −0.0 (x + −x rounds to +0.0; −0.0 needs −0.0 + −0.0),
/// so the unstored positions' ±0.0 products are bitwise no-ops in the
/// dense fold. Feeding only the stored entries — ascending, so each
/// lane sees its products in the dense order — therefore reproduces the
/// dense block dot **bit for bit** in O(nnz_block) work.
#[inline]
fn sparse_block_dot(idx: &[u32], vals: &[f32], y: &[f32], kb: usize, chunks8: usize) -> f32 {
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    let (mut s4, mut s5, mut s6, mut s7) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    let mut tail = 0.0f32;
    for (&i, &v) in idx.iter().zip(vals) {
        let off = i as usize - kb;
        let p = v * y[off];
        if off < chunks8 {
            match off & 7 {
                0 => s0 += p,
                1 => s1 += p,
                2 => s2 += p,
                3 => s3 += p,
                4 => s4 += p,
                5 => s5 += p,
                6 => s6 += p,
                _ => s7 += p,
            }
        } else {
            tail += p;
        }
    }
    ((s0 + s1) + (s2 + s3)) + ((s4 + s5) + (s6 + s7)) + tail
}

impl ComputeBackend for NativeBackend {
    /// Fused cache-blocked gram: per worker row, the j-panel's dots are
    /// accumulated over ascending kb blocks and κ is applied the moment
    /// a panel's dots are finished. κ is a pure function of the
    /// completed dot, so this equals the two-pass GEMM + `apply_tile`
    /// bit-for-bit, at every thread count.
    fn gram_tile(
        &self,
        a: &DenseMatrix,
        b: &DenseMatrix,
        kernel: &KernelFn,
        row_norms: &[f32],
        col_norms: &[f32],
    ) -> DenseMatrix {
        assert_eq!(a.cols(), b.cols(), "gram_tile: inner dims differ");
        let (m, n, d) = (a.rows(), b.rows(), a.cols());
        let norms = kernel.needs_norms();
        if norms {
            assert_eq!(row_norms.len(), m);
            assert_eq!(col_norms.len(), n);
        }
        let mut c = DenseMatrix::zeros(m, n);
        {
            let cptr = SendPtr(c.data_mut().as_mut_ptr());
            par_ranges_with(self.threads, m, PAR_MIN_ROWS, |lo, hi| {
                let cptr = &cptr;
                for i in lo..hi {
                    // SAFETY: rows [lo,hi) are exclusive to this worker.
                    let crow = unsafe { std::slice::from_raw_parts_mut(cptr.0.add(i * n), n) };
                    let nx = if norms { row_norms[i] } else { 0.0 };
                    for jb in (0..n).step_by(BLOCK_J) {
                        let jend = (jb + BLOCK_J).min(n);
                        for kb in (0..d).step_by(BLOCK_K) {
                            let kend = (kb + BLOCK_K).min(d);
                            let arow = &a.row(i)[kb..kend];
                            for (j, cj) in crow[jb..jend].iter_mut().enumerate() {
                                *cj += ops::dot(arow, &b.row(jb + j)[kb..kend]);
                            }
                        }
                        for (j, cj) in crow[jb..jend].iter_mut().enumerate() {
                            let ny = if norms { col_norms[jb + j] } else { 0.0 };
                            *cj = kernel.apply(*cj, nx, ny);
                        }
                    }
                }
            });
        }
        c
    }

    /// Sparse cross-kernel gram C = κ(X_sparse · Lᵀ): same row
    /// ownership, same jb/kb blocking, and — via [`sparse_block_dot`] —
    /// the same per-element f32 fold as the dense `gram_tile`, but the
    /// inner work is O(nnz_row · n) instead of O(d · n). All-zero kb
    /// blocks are skipped outright: the dense path adds their exactly
    /// +0.0 block dot to a partial that is never −0.0, a bitwise no-op.
    fn gram_tile_csr(
        &self,
        a: &CsrMatrix,
        b: &DenseMatrix,
        kernel: &KernelFn,
        row_norms: &[f32],
        col_norms: &[f32],
    ) -> DenseMatrix {
        assert_eq!(a.cols(), b.cols(), "gram_tile_csr: inner dims differ");
        let (m, n, d) = (a.rows(), b.rows(), a.cols());
        let norms = kernel.needs_norms();
        if norms {
            assert_eq!(row_norms.len(), m);
            assert_eq!(col_norms.len(), n);
        }
        let mut c = DenseMatrix::zeros(m, n);
        {
            let cptr = SendPtr(c.data_mut().as_mut_ptr());
            par_ranges_with(self.threads, m, PAR_MIN_ROWS, |lo, hi| {
                let cptr = &cptr;
                for i in lo..hi {
                    // SAFETY: rows [lo,hi) are exclusive to this worker.
                    let crow = unsafe { std::slice::from_raw_parts_mut(cptr.0.add(i * n), n) };
                    let (aidx, avals) = a.row(i);
                    let nx = if norms { row_norms[i] } else { 0.0 };
                    for jb in (0..n).step_by(BLOCK_J) {
                        let jend = (jb + BLOCK_J).min(n);
                        // Entry cursor over the (ascending) CSR row:
                        // [e0, e1) are the entries inside each kb block.
                        let mut e0 = 0usize;
                        for kb in (0..d).step_by(BLOCK_K) {
                            let kend = (kb + BLOCK_K).min(d);
                            let mut e1 = e0;
                            while e1 < aidx.len() && (aidx[e1] as usize) < kend {
                                e1 += 1;
                            }
                            if e1 > e0 {
                                let chunks8 = ((kend - kb) / 8) * 8;
                                let (bidx, bvals) = (&aidx[e0..e1], &avals[e0..e1]);
                                for (j, cj) in crow[jb..jend].iter_mut().enumerate() {
                                    let brow = &b.row(jb + j)[kb..kend];
                                    *cj += sparse_block_dot(bidx, bvals, brow, kb, chunks8);
                                }
                            }
                            e0 = e1;
                        }
                        for (j, cj) in crow[jb..jend].iter_mut().enumerate() {
                            let ny = if norms { col_norms[jb + j] } else { 0.0 };
                            *cj = kernel.apply(*cj, nx, ny);
                        }
                    }
                }
            });
        }
        c
    }

    fn matmul_nn_acc(&self, a: &DenseMatrix, b: &DenseMatrix, c: &mut DenseMatrix) {
        ops::matmul_nn_acc_with(self.threads, a, b, c);
    }

    fn kernel_apply(
        &self,
        b: &mut DenseMatrix,
        kernel: &KernelFn,
        row_norms: &[f32],
        col_norms: &[f32],
    ) {
        let norms = kernel.needs_norms();
        if norms {
            assert_eq!(row_norms.len(), b.rows());
            assert_eq!(col_norms.len(), b.cols());
        }
        let (m, n) = (b.rows(), b.cols());
        let bptr = SendPtr(b.data_mut().as_mut_ptr());
        par_ranges_with(self.threads, m, PAR_MIN_ELEM_ROWS, |lo, hi| {
            let bptr = &bptr;
            for i in lo..hi {
                // SAFETY: rows [lo,hi) are exclusive to this worker.
                let row = unsafe { std::slice::from_raw_parts_mut(bptr.0.add(i * n), n) };
                let nx = if norms { row_norms[i] } else { 0.0 };
                for (j, v) in row.iter_mut().enumerate() {
                    let ny = if norms { col_norms[j] } else { 0.0 };
                    *v = kernel.apply(*v, nx, ny);
                }
            }
        });
    }

    fn spmm_vk(
        &self,
        k_tile: &DenseMatrix,
        assign_r: &[u32],
        k: usize,
        inv_sizes: &[f32],
    ) -> DenseMatrix {
        sparse::ops::spmm_vk(k_tile, assign_r, k, inv_sizes)
    }

    fn spmm_vk_t(
        &self,
        k_tile: &DenseMatrix,
        assign_r: &[u32],
        k: usize,
        inv_sizes: &[f32],
    ) -> DenseMatrix {
        sparse::ops::spmm_vk_t(k_tile, assign_r, k, inv_sizes)
    }

    /// Workers own disjoint *column* ranges and every worker folds the
    /// input rows in the same ascending-j order the sequential loop
    /// uses, so each output element sees the identical f32 addition
    /// sequence at every thread count.
    fn cluster_row_sums(
        &self,
        c_rows: &DenseMatrix,
        assign: &[u32],
        k: usize,
        w: usize,
    ) -> Vec<f32> {
        assert_eq!(c_rows.rows(), assign.len());
        assert_eq!(c_rows.cols(), w, "cluster_row_sums: tile width differs from w");
        let mut b = vec![0.0f32; k * w];
        {
            let bptr = SendPtr(b.as_mut_ptr());
            par_ranges_with(self.threads, w, PAR_MIN_COLS, |clo, chi| {
                let bptr = &bptr;
                for (j, &a) in assign.iter().enumerate() {
                    let row = c_rows.row(j);
                    let base = a as usize * w;
                    for (col, v) in row[clo..chi].iter().enumerate() {
                        // SAFETY: columns [clo,chi) of every cluster row
                        // are exclusive to this worker.
                        unsafe { *bptr.0.add(base + clo + col) += v };
                    }
                }
            });
        }
        b
    }

    fn mask_z(&self, e_local: &DenseMatrix, assign: &[u32]) -> Vec<f32> {
        assert_eq!(e_local.rows(), assign.len());
        let n = assign.len();
        let mut z = vec![0.0f32; n];
        {
            let zptr = SendPtr(z.as_mut_ptr());
            par_ranges_with(self.threads, n, PAR_MIN_ELEM_ROWS, |lo, hi| {
                let zptr = &zptr;
                for (j, &a) in assign[lo..hi].iter().enumerate() {
                    // SAFETY: indices [lo,hi) exclusive to this worker.
                    unsafe { *zptr.0.add(lo + j) = e_local.get(lo + j, a as usize) };
                }
            });
        }
        z
    }

    fn spmv_vz(&self, assign: &[u32], z: &[f32], k: usize, inv_sizes: &[f32]) -> Vec<f32> {
        sparse::ops::spmv_vz(assign, z, k, inv_sizes)
    }

    fn distances_argmin(&self, e_local: &DenseMatrix, c: &[f32]) -> (Vec<u32>, Vec<f32>) {
        let k = e_local.cols();
        assert_eq!(c.len(), k);
        let m = e_local.rows();
        let mut arg = vec![0u32; m];
        let mut val = vec![0.0f32; m];
        {
            let aptr = SendPtr(arg.as_mut_ptr());
            let vptr = SendPtr(val.as_mut_ptr());
            par_ranges_with(self.threads, m, PAR_MIN_ELEM_ROWS, |lo, hi| {
                let (aptr, vptr) = (&aptr, &vptr);
                for j in lo..hi {
                    let row = e_local.row(j);
                    let mut best = 0usize;
                    let mut best_d = -2.0 * row[0] + c[0];
                    for a in 1..k {
                        let d = -2.0 * row[a] + c[a];
                        // Strict < : ties break to the lower cluster index.
                        if d < best_d {
                            best_d = d;
                            best = a;
                        }
                    }
                    // SAFETY: rows [lo,hi) exclusive to this worker.
                    unsafe {
                        *aptr.0.add(j) = best as u32;
                        *vptr.0.add(j) = best_d;
                    }
                }
            });
        }
        (arg, val)
    }

    fn name(&self) -> &str {
        match self.threads {
            0 => "native",
            1 => "native-scalar",
            _ => "native-threaded",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn gram_tile_fuses_kernel() {
        let mut rng = Rng::new(2);
        let a = DenseMatrix::random(4, 3, &mut rng);
        let b = DenseMatrix::random(5, 3, &mut rng);
        let be = NativeBackend::new();
        let kf = KernelFn::paper_polynomial();
        let tile = be.gram_tile(&a, &b, &kf, &[], &[]);
        for i in 0..4 {
            for j in 0..5 {
                let dot = ops::dot(a.row(i), b.row(j));
                assert!((tile.get(i, j) - kf.apply(dot, 0.0, 0.0)).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn fused_gram_matches_two_pass_bitwise() {
        // The fused epilogue must equal GEMM-then-apply_tile exactly —
        // not approximately — for every kernel family, because the
        // oracle tests and the scalar/threaded wall compare with `==`.
        let mut rng = Rng::new(7);
        let a = DenseMatrix::random(33, 300, &mut rng);
        let b = DenseMatrix::random(21, 300, &mut rng);
        let (an, bn) = (a.row_sq_norms(), b.row_sq_norms());
        for kf in [KernelFn::linear(), KernelFn::paper_polynomial(), KernelFn::gaussian(0.3)] {
            let (rn, cn): (&[f32], &[f32]) =
                if kf.needs_norms() { (&an, &bn) } else { (&[], &[]) };
            let mut two_pass = ops::matmul_nt(&a, &b);
            kf.apply_tile(&mut two_pass, rn, cn);
            for threads in [1usize, 2, 4, 8] {
                let be = NativeBackend::threaded(threads);
                let fused = be.gram_tile(&a, &b, &kf, rn, cn);
                assert_eq!(fused.data(), two_pass.data(), "{} @ {threads} threads", kf.tag());
            }
        }
    }

    #[test]
    fn sparse_gram_matches_dense_bitwise() {
        // The lane-replay CSR gram vs the dense fused gram: exact ==,
        // every kernel family, several densities (a fully-zero row
        // included), thread counts 1..8, and d values exercising both
        // the kb blocking (d > BLOCK_K) and the 8-lane tail (d % 8 ≠ 0).
        let mut rng = Rng::new(29);
        for (rows, d, keep) in [(19usize, 300usize, 3usize), (33, 523, 7), (9, 40, 2)] {
            let a = DenseMatrix::from_fn(rows, d, |i, j| {
                let v = rng.next_f32() - 0.5;
                if i != 5 && (i + j) % keep == 0 {
                    v
                } else {
                    0.0
                }
            });
            let b = DenseMatrix::random(21, d, &mut rng);
            let sa = CsrMatrix::from_dense(&a);
            assert!(sa.nnz() < rows * d);
            let (an, bn) = (sa.row_sq_norms(), b.row_sq_norms());
            assert_eq!(an, a.row_sq_norms(), "sparse norms must match dense bitwise");
            for kf in [KernelFn::linear(), KernelFn::paper_polynomial(), KernelFn::gaussian(0.3)] {
                let (rn, cn): (&[f32], &[f32]) =
                    if kf.needs_norms() { (&an, &bn) } else { (&[], &[]) };
                let dense = NativeBackend::scalar().gram_tile(&a, &b, &kf, rn, cn);
                for threads in [1usize, 2, 4, 8] {
                    let be = NativeBackend::threaded(threads);
                    let sp = be.gram_tile_csr(&sa, &b, &kf, rn, cn);
                    assert_eq!(
                        sp.data(),
                        dense.data(),
                        "{} @ {threads} threads, shape ({rows},{d})",
                        kf.tag()
                    );
                }
            }
        }
    }

    #[test]
    fn sparse_gram_keeps_explicit_zero_entries_bit_identical() {
        // A stored explicit 0.0 entry multiplies to ±0.0 and must fold
        // as a no-op — same bits as the dense path that also sees it.
        let mut rng = Rng::new(31);
        let b = DenseMatrix::random(5, 12, &mut rng);
        let sa = CsrMatrix::from_rows(
            12,
            &[vec![(0, 1.5), (3, 0.0), (9, -2.0)], vec![(11, 4.0)], vec![]],
        );
        let a = sa.to_dense();
        let kf = KernelFn::paper_polynomial();
        let be = NativeBackend::scalar();
        assert_eq!(
            be.gram_tile_csr(&sa, &b, &kf, &[], &[]).data(),
            be.gram_tile(&a, &b, &kf, &[], &[]).data()
        );
    }

    #[test]
    fn cluster_row_sums_matches_default_at_all_thread_counts() {
        let mut rng = Rng::new(11);
        let (n, k, w) = (157, 5, 67);
        let c = DenseMatrix::random(n, w, &mut rng);
        let assign: Vec<u32> = (0..n).map(|j| (j * 7 % k) as u32).collect();
        // The trait default's sequential loop is the reference.
        fn reference(c: &DenseMatrix, assign: &[u32], k: usize, w: usize) -> Vec<f32> {
            let mut b = vec![0.0f32; k * w];
            for (j, &a) in assign.iter().enumerate() {
                let row = c.row(j);
                let acc = &mut b[a as usize * w..(a as usize + 1) * w];
                for (s, v) in acc.iter_mut().zip(row) {
                    *s += v;
                }
            }
            b
        }
        let expect = reference(&c, &assign, k, w);
        for threads in [1usize, 2, 4, 8] {
            let be = NativeBackend::threaded(threads);
            assert_eq!(be.cluster_row_sums(&c, &assign, k, w), expect, "@ {threads} threads");
        }
    }

    #[test]
    fn rowwise_kernels_are_thread_invariant() {
        let mut rng = Rng::new(13);
        let (n, k) = (611, 6);
        let e = DenseMatrix::random(n, k, &mut rng);
        let c: Vec<f32> = (0..k).map(|a| a as f32 * 0.37 - 1.0).collect();
        let assign: Vec<u32> = (0..n).map(|j| (j * 5 % k) as u32).collect();
        let s = NativeBackend::scalar();
        let (arg1, val1) = s.distances_argmin(&e, &c);
        let z1 = s.mask_z(&e, &assign);
        for threads in [2usize, 4, 8] {
            let be = NativeBackend::threaded(threads);
            let (arg, val) = be.distances_argmin(&e, &c);
            assert_eq!(arg, arg1, "argmin arg @ {threads}");
            assert_eq!(val, val1, "argmin val @ {threads}");
            assert_eq!(be.mask_z(&e, &assign), z1, "mask_z @ {threads}");
        }
    }

    #[test]
    fn mask_z_selects_assigned_column() {
        let e = DenseMatrix::from_fn(3, 2, |i, j| (i * 2 + j) as f32);
        let be = NativeBackend::new();
        let z = be.mask_z(&e, &[1, 0, 1]);
        assert_eq!(z, vec![1.0, 2.0, 5.0]);
    }

    #[test]
    fn argmin_tie_breaks_low() {
        // Row where clusters 0 and 1 tie exactly.
        let e = DenseMatrix::from_vec(1, 3, vec![1.0, 1.0, 0.0]);
        let c = vec![0.0, 0.0, 0.0];
        let be = NativeBackend::new();
        let (arg, val) = be.distances_argmin(&e, &c);
        assert_eq!(arg, vec![0]);
        assert_eq!(val, vec![-2.0]);
    }

    #[test]
    fn argmin_uses_centroid_norms() {
        // E identical across clusters; c decides.
        let e = DenseMatrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = vec![5.0, 1.0];
        let be = NativeBackend::new();
        let (arg, _) = be.distances_argmin(&e, &c);
        assert_eq!(arg, vec![1, 1]);
    }
}
