//! Distributed SpMM algorithms for Eᵀ = V·K.
//!
//! V has one nonzero per column, so its wire form is the per-point
//! assignment vector (u32 indices only — paper §V); the dense operand K
//! never moves (all three variants are B-stationary, the paper's
//! communication-avoiding choice for the huge K).
//!
//! * [`onedim`] — Allgather the whole assignment vector, local SpMM
//!   against the 1D block row of K: α·O(P) + β·O(n) — Eq. (15).
//! * [`twodim`] — V tiles allgathered along grid rows, partial Eᵀ
//!   reduce-scattered along grid columns by **cluster blocks**, leaving
//!   Eᵀ 2D-partitioned: α·O(√P) + β·O(n(k+1)/√P) — Eq. (18) — but
//!   cluster updates then need the MINLOC allreduce (Eq. 19).
//! * [`onefived`] — the paper's main contribution: V stays 1D, K stays
//!   2D; gather-to-diagonal + row broadcast replicates the needed V
//!   slices, and the reduce-scatter is split along **columns** so Eᵀ
//!   lands 1D-columnwise on contiguous ranks (column-major grid) —
//!   cluster updates need **no** communication:
//!   α·O(√P) + β·O(n(k+1)/√P) — Eq. (25).
//!
//! Layout reminder (see [`crate::sparse::ops`]): local E is stored as
//! (points × k) row-major = Eᵀ column-major, so the 1.5D column split
//! is a contiguous memory split.

pub mod onedim;
pub mod twodim;
pub mod onefived;

pub use onedim::spmm_1d;
pub use onefived::spmm_15d;
pub use twodim::spmm_2d;
