//! Kernel K-means vs plain (Lloyd) K-means on non-linearly-separable
//! data — the paper's §I motivation, quantified.
//!
//! Runs both algorithms on three geometries (blobs, rings, moons) and
//! prints an NMI comparison table: blobs are easy for both; rings and
//! moons defeat Lloyd but not the kernelized algorithm.
//!
//! Run: `cargo run --release --example nonlinear_clusters`

use vivaldi::data::synth;
use vivaldi::kernelfn::KernelFn;
use vivaldi::kkmeans::{self, Algo, FitConfig};
use vivaldi::lloyd::lloyd_fit;
use vivaldi::metrics::Table;
use vivaldi::quality::nmi;

fn main() {
    let cases = vec![
        ("blobs", synth::gaussian_blobs(1200, 8, 3, 4.0, 7), 3, KernelFn::paper_polynomial()),
        ("rings", synth::concentric_rings(1200, 2, 7), 2, KernelFn::gaussian(2.0)),
        ("moons", synth::two_moons(1200, 0.08, 7), 2, KernelFn::gaussian(8.0)),
    ];

    let mut table = Table::new(
        "Kernel K-means (1.5D, 4 ranks) vs Lloyd — NMI against ground truth",
        &["dataset", "k", "kernel", "NMI lloyd", "NMI kernel", "winner"],
    );

    for (name, ds, k, kernel) in cases {
        let lloyd = lloyd_fit(&ds.points, k, 100);
        let nmi_lloyd = nmi(&lloyd.assignments, &ds.labels, k);

        let cfg = FitConfig { k, max_iters: 100, kernel, ..Default::default() };
        let kk = kkmeans::fit(Algo::OneFiveD, 4, &ds.points, &cfg).expect("fit");
        let nmi_kernel = nmi(&kk.assignments, &ds.labels, k);

        table.row(vec![
            name.into(),
            k.to_string(),
            kernel.tag().into(),
            format!("{nmi_lloyd:.3}"),
            format!("{nmi_kernel:.3}"),
            if nmi_kernel > nmi_lloyd + 0.05 {
                "kernel".into()
            } else if nmi_lloyd > nmi_kernel + 0.05 {
                "lloyd".into()
            } else {
                "tie".into()
            },
        ]);
    }
    table.print();
    println!("Expected: tie on blobs, kernel wins rings + moons.");
}
