//! Deterministic, seedable PRNG (xoshiro256++ seeded via SplitMix64).
//!
//! The vendored dependency set has no `rand`, so experiments and tests
//! use this small generator. It is deterministic across platforms, which
//! the integration tests rely on (distributed runs are compared
//! bit-for-bit against a single-rank oracle fed the same seed).

/// xoshiro256++ generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

impl Rng {
    /// Seed the generator. Any u64 works (including 0).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw u64.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = rotl(self.s[3], 45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 high bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free approximation is fine here: the
        // modulo bias for n << 2^64 is negligible for experiments, but we
        // use widening multiply for uniformity anyway.
        let m = (self.next_u64() as u128).wrapping_mul(n as u128);
        (m >> 64) as usize
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box-Muller (one value per call, cache-free).
    pub fn normal(&mut self) -> f64 {
        // Avoid log(0).
        let u1 = loop {
            let u = self.next_f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `m` distinct indices from [0, n) (m <= n), in random order.
    pub fn sample_indices(&mut self, n: usize, m: usize) -> Vec<usize> {
        assert!(m <= n);
        // Partial Fisher-Yates over an index vector; fine for test scale.
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..m {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(m);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.below(7);
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(13);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut u = s.clone();
        u.sort_unstable();
        u.dedup();
        assert_eq!(u.len(), 20);
        assert!(u.iter().all(|&i| i < 50));
    }
}
