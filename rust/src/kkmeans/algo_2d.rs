//! The pure 2D Kernel K-means algorithm (paper §IV.B, second
//! alternative).
//!
//! SUMMA K stays 2D; V is 2D-partitioned to match (rank (i,j) stores
//! the assignment slice for sub-slice j of point block i). The 2D SpMM
//! leaves Eᵀ 2D-partitioned — clusters block i × points block j on
//! rank (i,j) — which is precisely why cluster updates now cost
//! communication:
//!
//! * c: partial sums per cluster block, Allreduced **along process
//!   rows** (paper §V.B);
//! * argmin: each rank minimizes over its own cluster block only, then
//!   an **MPI_MINLOC Allreduce along process columns** (8 B/point — the
//!   buffer-doubling the paper calls out) resolves the global winner —
//!   Eq. (19), the term that stops 2D from matching 1.5D;
//! * V refresh: the slice a rank feeds the next SpMM belongs to its
//!   *row* block, but new assignments are resolved per *column* block;
//!   a transpose pairwise exchange with rank (j,i) delivers it (the
//!   paper leaves this step implicit; the n/P-word exchange is
//!   asymptotically free next to the MINLOC allreduce).

use crate::backend::ComputeBackend;
use crate::comm::{Comm, Grid2D, Group};
use crate::dense::DenseMatrix;
use crate::gemm::{summa_gram, SummaPointTiles};
use crate::layout::{harness, Partition};
use crate::spmm::spmm_2d;
use crate::util::{part, timing::Stopwatch};
use crate::VivaldiError;

use super::loop_common;
use super::{FitConfig, RankOutput};

pub(super) fn run_rank(
    comm: &Comm,
    points: &DenseMatrix,
    cfg: &FitConfig,
    backend: &dyn ComputeBackend,
) -> Result<RankOutput, VivaldiError> {
    let p = comm.size();
    let n = points.rows();
    let d = points.cols();
    let k = cfg.k;
    let world = Group::world(p);
    let grid = Grid2D::new(p).expect("fit() checked square grid");
    let q = grid.q();
    let (i, j) = grid.coords(comm.rank());
    let row_g = grid.row_group(i);
    let col_g = grid.col_group(j);
    let (_mem, tracker) = harness::rank_tracker(comm.rank(), cfg.mem);
    let mut sw = Stopwatch::new();

    let tiles = SummaPointTiles::from_global(points, &grid, comm.rank());
    let k_tile = sw.time("gemm", || {
        summa_gram(comm, &grid, &tiles, n, d, &cfg.kernel, backend, &tracker)
    })?;

    let layout = Partition::tiles_2d(n, p).expect("fit() checked square grid");
    // Point ranges.
    let (bj_lo, bj_hi) = part::bounds(n, q, j); // my column's point block
    // V slice fed to the SpMM: sub-slice j of row block i.
    let (vi_lo, vi_hi) = part::nested(n, q, i, j);
    // Canonical output slice: sub-slice i of column block j.
    let (own_lo, own_hi) = layout.owned_range(comm.rank());

    // Round-robin init.
    let mut v_slice: Vec<u32> = (vi_lo..vi_hi).map(|x| (x % k) as u32).collect();
    let mut assign_block_j: Vec<u32> = (bj_lo..bj_hi).map(|x| (x % k) as u32).collect();
    comm.set_phase("update");
    let own_assign = |abj: &[u32]| abj[own_lo - bj_lo..own_hi - bj_lo].to_vec();
    let mut sizes = loop_common::global_sizes(comm, &world, &own_assign(&assign_block_j), k);

    let outcome = harness::drive_loop(cfg.max_iters, cfg.converge_on_stable, |_| {
        let inv = loop_common::inv_sizes(&sizes);
        // 2D B-stationary SpMM: Eᵀ tile, clusters [clo,chi) × block j.
        let et = sw.time("spmm", || {
            spmm_2d(comm, &grid, &k_tile, &v_slice, n, k, &inv, backend)
        });
        let (clo, chi) = et.cluster_range;
        let n_j = et.tile.cols();

        let t_update = crate::util::timing::clock_now();
        comm.set_phase("update");
        // c partials for my cluster block over my point block (Eq. 5–6,
        // restricted to rows I own).
        let mut c_part = vec![0.0f32; chi - clo];
        for (c_idx, &a) in assign_block_j.iter().enumerate() {
            let a = a as usize;
            if a >= clo && a < chi {
                c_part[a - clo] += et.tile.get(a - clo, c_idx);
            }
        }
        for (a_off, v) in c_part.iter_mut().enumerate() {
            *v *= inv[clo + a_off];
        }
        // Allreduce along the process row (paper §V.B).
        let c_block = comm.allreduce_sum_f32(&row_g, c_part);

        // Local argmin over my cluster block.
        let mut vals = vec![f32::INFINITY; n_j];
        let mut locs = vec![0u32; n_j];
        for a in clo..chi {
            let ca = c_block[a - clo];
            let row = et.tile.row(a - clo);
            for (c_idx, &e) in row.iter().enumerate() {
                let dist = -2.0 * e + ca;
                if dist < vals[c_idx] {
                    vals[c_idx] = dist;
                    locs[c_idx] = a as u32;
                }
            }
        }
        // Global winner per point: MINLOC along the process column
        // (8 B per point — the paper's doubled buffer).
        let (minvals, new_assign_block_j) = comm.allreduce_minloc(&col_g, vals, locs);

        // Change count + objective: block j is shared by the whole
        // process column; row 0 contributes, everyone calls the
        // collective.
        let (local_changes, local_obj) = if i == 0 {
            let ch = assign_block_j
                .iter()
                .zip(&new_assign_block_j)
                .filter(|(o, n)| o != n)
                .count() as u64;
            let ob: f64 = minvals.iter().map(|&v| v as f64).sum();
            (ch, ob)
        } else {
            (0, 0.0)
        };
        let changes = comm.allreduce_sum_u64(&world, vec![local_changes])[0];
        let obj = loop_common::allreduce_sum_f64(comm, &world, local_obj);
        assign_block_j = new_assign_block_j;

        // Global cluster sizes from disjoint canonical slices.
        sizes = loop_common::global_sizes(comm, &world, &own_assign(&assign_block_j), k);

        // V refresh: transpose exchange with partner (j,i). I know the
        // new block j; my partner needs sub-slice i of block j (its
        // v_slice); I need sub-slice j of block i (mine).
        let partner = grid.rank_at(j, i);
        let tag = comm.next_tag(&world);
        let outgoing = own_assign(&assign_block_j); // = nested(n,q,j,i)
        if partner == comm.rank() {
            v_slice = outgoing;
        } else {
            comm.send(partner, tag, outgoing);
            v_slice = comm.recv(partner, tag);
        }
        debug_assert_eq!(v_slice.len(), vi_hi - vi_lo);
        sw.add("update", crate::util::timing::clock_now() - t_update);
        (changes, obj)
    });

    Ok(harness::finish_rank(own_assign(&assign_block_j), sw, outcome, &tracker))
}

#[cfg(test)]
mod tests {
    use super::super::{fit, Algo, FitConfig};
    use crate::data::synth;
    use crate::kernelfn::KernelFn;

    #[test]
    fn matches_1d_on_separable_data() {
        let ds = synth::gaussian_blobs(80, 4, 4, 4.0, 37);
        let cfg = FitConfig {
            k: 4,
            max_iters: 40,
            kernel: KernelFn::linear(),
            ..Default::default()
        };
        let ref_out = fit(Algo::OneD, 1, &ds.points, &cfg).unwrap();
        for p in [1usize, 4] {
            let out = fit(Algo::TwoD, p, &ds.points, &cfg).unwrap();
            assert_eq!(out.assignments, ref_out.assignments, "p={p}");
        }
    }

    #[test]
    fn sixteen_ranks_polynomial() {
        let ds = synth::gaussian_blobs(160, 6, 4, 4.0, 38);
        let cfg = FitConfig { k: 4, max_iters: 50, ..Default::default() };
        let ref_out = fit(Algo::OneFiveD, 16, &ds.points, &cfg).unwrap();
        let out = fit(Algo::TwoD, 16, &ds.points, &cfg).unwrap();
        // Same fixed point on well-separated data.
        assert_eq!(out.assignments, ref_out.assignments);
        for w in out.objective_curve.windows(2) {
            assert!(w[1] <= w[0] + 1e-3);
        }
    }

    #[test]
    fn update_phase_costs_more_than_15d() {
        // The MINLOC allreduce makes 2D's update phase communicate
        // O(n/√P·log√P) words/rank vs 1.5D's O(k): Eq. 19 vs "none".
        let ds = synth::gaussian_blobs(288, 4, 4, 3.0, 39);
        let cfg =
            FitConfig { k: 4, max_iters: 10, converge_on_stable: false, ..Default::default() };
        let two = fit(Algo::TwoD, 9, &ds.points, &cfg).unwrap();
        let fifteen = fit(Algo::OneFiveD, 9, &ds.points, &cfg).unwrap();
        let up2: u64 = two.comm_stats.iter().map(|s| s.get("update").bytes).sum();
        let up15: u64 = fifteen.comm_stats.iter().map(|s| s.get("update").bytes).sum();
        assert!(up2 > 2 * up15, "2D update {up2} vs 1.5D update {up15}");
    }
}
