//! Chunked point sources for the streaming landmark path
//! ([`crate::approx::stream`]).
//!
//! Every batch path in the crate assumes the full point set is resident
//! before `fit` runs; a [`PointSource`] inverts that contract — points
//! arrive in caller-sized chunks, and only the chunk in flight is ever
//! materialized. Two sources cover the repo's data story:
//!
//! * [`MatrixSource`] wraps an in-memory matrix (everything the
//!   [`super::synth`] / [`super::datasets`] generators produce) so the
//!   streaming driver can be tested against the batch path on identical
//!   data.
//! * [`LibsvmSource`] reads a libSVM file incrementally with a fixed
//!   feature width — the real Table-II files never need to be densified
//!   whole.

use super::Dataset;
use crate::dense::DenseMatrix;
use crate::sparse::CsrMatrix;
use std::io::{BufRead, BufReader};
use std::path::Path;

/// A sequential source of points with a fixed feature dimension.
///
/// `next_batch(b)` yields the next at-most-`b` rows, `Ok(None)` once
/// the source is cleanly exhausted, or `Err` on a mid-stream failure
/// (an I/O error halfway through a file) — an error is **not** end of
/// stream, so a broken feed can never silently truncate into a
/// "successful" fit. Implementations must be deterministic: the same
/// source replayed with the same batch sizes yields the same rows in
/// the same order (the streaming tests replay sources against the batch
/// oracle).
pub trait PointSource {
    /// Feature dimension of every batch this source yields.
    fn dim(&self) -> usize;

    /// The next chunk of at most `max_rows` rows (`Ok(None)` = cleanly
    /// exhausted; `Err` = the stream broke mid-flight).
    fn next_batch(&mut self, max_rows: usize) -> Result<Option<DenseMatrix>, String>;

    /// The next chunk in CSR form (the sparse streaming lane's pull).
    ///
    /// The default densifies a `next_batch` chunk and re-sparsifies —
    /// correct for every source and bit-identical downstream (dropped
    /// zeros fold as exactly +0.0). Sparse-native sources
    /// ([`SparseLibsvmSource`]) override it to build CSR straight from
    /// the parsed rows, so peak memory is ∝ batch·nnz, never ∝ batch·d.
    fn next_batch_csr(&mut self, max_rows: usize) -> Result<Option<CsrMatrix>, String> {
        Ok(self.next_batch(max_rows)?.map(|b| CsrMatrix::from_dense(&b)))
    }

    /// Total rows, when known up front (generators know; files may not).
    fn hint_total(&self) -> Option<usize> {
        None
    }
}

/// Stream an in-memory matrix in row-block chunks (zero-copy slicing of
/// the wrapped generator output).
pub struct MatrixSource<'a> {
    points: &'a DenseMatrix,
    cursor: usize,
}

impl<'a> MatrixSource<'a> {
    pub fn new(points: &'a DenseMatrix) -> Self {
        MatrixSource { points, cursor: 0 }
    }

    /// Wrap a generated [`Dataset`]'s points (labels stay with the
    /// caller — the stream carries points only, like a real feed).
    pub fn from_dataset(ds: &'a Dataset) -> Self {
        Self::new(&ds.points)
    }

    /// Rows already handed out.
    pub fn consumed(&self) -> usize {
        self.cursor
    }
}

impl PointSource for MatrixSource<'_> {
    fn dim(&self) -> usize {
        self.points.cols()
    }

    fn next_batch(&mut self, max_rows: usize) -> Result<Option<DenseMatrix>, String> {
        assert!(max_rows >= 1, "batch size must be positive");
        let n = self.points.rows();
        if self.cursor >= n {
            return Ok(None);
        }
        let hi = (self.cursor + max_rows).min(n);
        let block = self.points.row_block(self.cursor, hi);
        self.cursor = hi;
        Ok(Some(block))
    }

    fn hint_total(&self) -> Option<usize> {
        Some(self.points.rows())
    }
}

/// Incremental libSVM reader with a fixed feature width `d` (features
/// past `d` are dropped, exactly like [`super::libsvm::read_libsvm`]'s
/// `d_cap`). Labels are discarded — the stream is unsupervised input.
pub struct LibsvmSource<R: BufRead> {
    reader: R,
    d: usize,
    rows_read: usize,
    done: bool,
}

impl LibsvmSource<BufReader<std::fs::File>> {
    /// Open a libSVM file for streaming with feature width `d`.
    pub fn open(path: &Path, d: usize) -> std::io::Result<Self> {
        let f = std::fs::File::open(path)?;
        Ok(Self::from_reader(BufReader::new(f), d))
    }
}

impl<R: BufRead> LibsvmSource<R> {
    /// Stream from any buffered reader (tests use in-memory strings).
    pub fn from_reader(reader: R, d: usize) -> Self {
        assert!(d >= 1, "feature width must be positive");
        LibsvmSource { reader, d, rows_read: 0, done: false }
    }

    /// Rows parsed so far.
    pub fn rows_read(&self) -> usize {
        self.rows_read
    }
}

impl<R: BufRead> PointSource for LibsvmSource<R> {
    fn dim(&self) -> usize {
        self.d
    }

    fn next_batch(&mut self, max_rows: usize) -> Result<Option<DenseMatrix>, String> {
        assert!(max_rows >= 1, "batch size must be positive");
        if self.done {
            return Ok(None);
        }
        let mut data = Vec::with_capacity(max_rows * self.d);
        let mut rows = 0usize;
        let mut line = String::new();
        while rows < max_rows {
            line.clear();
            match self.reader.read_line(&mut line) {
                Ok(0) => {
                    self.done = true;
                    break;
                }
                // A mid-file read failure is an error, not end-of-file:
                // surfacing it (rather than truncating) is the whole
                // point of the Result contract.
                Err(e) => {
                    self.done = true;
                    return Err(format!(
                        "libSVM stream failed after {} rows: {e}",
                        self.rows_read + rows
                    ));
                }
                Ok(_) => {}
            }
            let parsed = match super::libsvm::parse_line(&line, Some(self.d)) {
                Ok(Some(p)) => p,
                Ok(None) => continue, // blank / comment line
                // Malformed tokens are stream failures, same contract
                // as a mid-file read error — never silently dropped.
                Err(msg) => {
                    self.done = true;
                    return Err(format!(
                        "libSVM parse error after {} rows: {msg}",
                        self.rows_read + rows
                    ));
                }
            };
            let row_start = data.len();
            data.resize(row_start + self.d, 0.0);
            for (idx, v) in parsed.features {
                data[row_start + idx] = v;
            }
            rows += 1;
        }
        if rows == 0 {
            return Ok(None);
        }
        self.rows_read += rows;
        Ok(Some(DenseMatrix::from_vec(rows, self.d, data)))
    }
}

/// Incremental libSVM reader that keeps every chunk in CSR form: the
/// sparse streaming lane's native source. Same dialect, `d`-cap
/// filtering, and fail-loud contract as [`LibsvmSource`], but
/// `next_batch_csr` builds the chunk straight from the parsed rows —
/// peak memory ∝ batch·nnz, so million-feature files stream through a
/// fixed budget the densifying source could never meet. (`next_batch`
/// still works, densifying one chunk, so the source remains a drop-in
/// [`PointSource`] anywhere.)
pub struct SparseLibsvmSource<R: BufRead> {
    reader: R,
    d: usize,
    rows_read: usize,
    nnz_read: u64,
    done: bool,
}

impl SparseLibsvmSource<BufReader<std::fs::File>> {
    /// Open a libSVM file for sparse streaming with feature width `d`.
    pub fn open(path: &Path, d: usize) -> std::io::Result<Self> {
        let f = std::fs::File::open(path)?;
        Ok(Self::from_reader(BufReader::new(f), d))
    }
}

impl<R: BufRead> SparseLibsvmSource<R> {
    /// Stream from any buffered reader (tests use in-memory strings).
    pub fn from_reader(reader: R, d: usize) -> Self {
        assert!(d >= 1, "feature width must be positive");
        SparseLibsvmSource { reader, d, rows_read: 0, nnz_read: 0, done: false }
    }

    /// Rows parsed so far.
    pub fn rows_read(&self) -> usize {
        self.rows_read
    }

    /// Stored entries parsed so far (the lane's memory currency).
    pub fn nnz_read(&self) -> u64 {
        self.nnz_read
    }
}

impl<R: BufRead> PointSource for SparseLibsvmSource<R> {
    fn dim(&self) -> usize {
        self.d
    }

    fn next_batch(&mut self, max_rows: usize) -> Result<Option<DenseMatrix>, String> {
        Ok(self.next_batch_csr(max_rows)?.map(|c| c.to_dense()))
    }

    fn next_batch_csr(&mut self, max_rows: usize) -> Result<Option<CsrMatrix>, String> {
        assert!(max_rows >= 1, "batch size must be positive");
        if self.done {
            return Ok(None);
        }
        let mut rows: Vec<Vec<(usize, f32)>> = Vec::new();
        let mut line = String::new();
        while rows.len() < max_rows {
            line.clear();
            match self.reader.read_line(&mut line) {
                Ok(0) => {
                    self.done = true;
                    break;
                }
                Err(e) => {
                    self.done = true;
                    return Err(format!(
                        "libSVM stream failed after {} rows: {e}",
                        self.rows_read + rows.len()
                    ));
                }
                Ok(_) => {}
            }
            match super::libsvm::parse_line(&line, Some(self.d)) {
                Ok(Some(p)) => rows.push(p.features),
                Ok(None) => continue, // blank / comment line
                Err(msg) => {
                    self.done = true;
                    return Err(format!(
                        "libSVM parse error after {} rows: {msg}",
                        self.rows_read + rows.len()
                    ));
                }
            }
        }
        if rows.is_empty() {
            return Ok(None);
        }
        self.rows_read += rows.len();
        let csr = CsrMatrix::from_rows(self.d, &rows);
        self.nnz_read += csr.nnz() as u64;
        Ok(Some(csr))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn matrix_source_chunks_cover_in_order() {
        let ds = synth::gaussian_blobs(100, 3, 2, 3.0, 5);
        let mut src = MatrixSource::from_dataset(&ds);
        assert_eq!(src.dim(), 3);
        assert_eq!(src.hint_total(), Some(100));
        let mut seen = Vec::new();
        while let Some(b) = src.next_batch(32).unwrap() {
            assert!(b.rows() <= 32);
            seen.push(b);
        }
        assert_eq!(seen.iter().map(|b| b.rows()).collect::<Vec<_>>(), vec![32, 32, 32, 4]);
        let back = DenseMatrix::vstack(&seen);
        assert_eq!(back, ds.points);
        assert_eq!(src.consumed(), 100);
        assert!(src.next_batch(32).unwrap().is_none());
    }

    #[test]
    fn matrix_source_single_batch_is_whole_set() {
        let ds = synth::concentric_rings(64, 2, 7);
        let mut src = MatrixSource::from_dataset(&ds);
        let b = src.next_batch(64).unwrap().unwrap();
        assert_eq!(b, ds.points);
        assert!(src.next_batch(64).unwrap().is_none());
    }

    #[test]
    fn libsvm_source_streams_fixed_width() {
        let text = "1 1:0.5 3:2.0\n-1 2:1.5\n\n# comment\n0 1:1 9:9\n2 4:4\n";
        let mut src = LibsvmSource::from_reader(std::io::Cursor::new(text), 4);
        assert_eq!(src.dim(), 4);
        let b1 = src.next_batch(2).unwrap().unwrap();
        assert_eq!((b1.rows(), b1.cols()), (2, 4));
        assert_eq!(b1.get(0, 0), 0.5);
        assert_eq!(b1.get(0, 2), 2.0);
        assert_eq!(b1.get(1, 1), 1.5);
        let b2 = src.next_batch(2).unwrap().unwrap();
        assert_eq!(b2.rows(), 2);
        assert_eq!(b2.get(0, 0), 1.0); // feature 9 dropped by the cap
        assert_eq!(b2.get(1, 3), 4.0);
        assert!(src.next_batch(2).unwrap().is_none());
        assert_eq!(src.rows_read(), 4);
    }

    #[test]
    fn libsvm_source_matches_batch_reader() {
        // Streaming chunks reassemble to exactly what read_libsvm sees.
        let ds = synth::gaussian_blobs(23, 4, 2, 3.0, 9);
        let dir = std::env::temp_dir().join("vivaldi_stream_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("stream.libsvm");
        crate::data::libsvm::write_libsvm(&path, &ds).unwrap();
        let whole = crate::data::libsvm::read_libsvm(&path, None, Some(4)).unwrap();
        let mut src = LibsvmSource::open(&path, 4).unwrap();
        let mut chunks = Vec::new();
        while let Some(b) = src.next_batch(7).unwrap() {
            chunks.push(b);
        }
        assert_eq!(DenseMatrix::vstack(&chunks), whole.points);
    }

    /// A reader that fails mid-stream: errors must surface as `Err`,
    /// not masquerade as a clean end of stream.
    struct FailingReader {
        fed: &'static [u8],
        pos: usize,
    }

    impl std::io::Read for FailingReader {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            if self.pos >= self.fed.len() {
                return Err(std::io::Error::other("disk went away"));
            }
            let n = buf.len().min(self.fed.len() - self.pos);
            buf[..n].copy_from_slice(&self.fed[self.pos..self.pos + n]);
            self.pos += n;
            Ok(n)
        }
    }

    #[test]
    fn libsvm_source_surfaces_midstream_errors() {
        let reader = std::io::BufReader::new(FailingReader { fed: b"1 1:1\n0 2:2\n", pos: 0 });
        let mut src = LibsvmSource::from_reader(reader, 3);
        let b = src.next_batch(2).unwrap().unwrap();
        assert_eq!(b.rows(), 2);
        // The next pull hits the failing read: an error, not Ok(None).
        let err = src.next_batch(2).unwrap_err();
        assert!(err.contains("after 2 rows"), "{err}");
        // And the source stays terminated afterwards.
        assert!(src.next_batch(2).unwrap().is_none());
    }

    #[test]
    fn libsvm_sources_surface_malformed_lines() {
        // A malformed token mid-stream is an Err on both sources, with
        // the row position — never a silent drop (fail-loud contract).
        let text = "1 1:0.5\n0 2:2\n-1 bogus\n";
        let mut dense = LibsvmSource::from_reader(std::io::Cursor::new(text), 3);
        assert_eq!(dense.next_batch(2).unwrap().unwrap().rows(), 2);
        let err = dense.next_batch(2).unwrap_err();
        assert!(err.contains("after 2 rows") && err.contains("bogus"), "{err}");
        assert!(dense.next_batch(2).unwrap().is_none(), "terminated after the error");

        let mut sparse = SparseLibsvmSource::from_reader(std::io::Cursor::new(text), 3);
        assert_eq!(sparse.next_batch_csr(2).unwrap().unwrap().rows(), 2);
        let err = sparse.next_batch_csr(2).unwrap_err();
        assert!(err.contains("after 2 rows") && err.contains("bogus"), "{err}");
        assert!(sparse.next_batch_csr(2).unwrap().is_none());
    }

    #[test]
    fn sparse_source_matches_dense_source_chunkwise() {
        let text = "1 1:0.5 3:2.0\n-1 2:1.5\n\n# comment\n0 1:1 9:9\n2 4:4\n1 2:0.25 4:8\n";
        let mut dense = LibsvmSource::from_reader(std::io::Cursor::new(text), 4);
        let mut sparse = SparseLibsvmSource::from_reader(std::io::Cursor::new(text), 4);
        assert_eq!(sparse.dim(), 4);
        loop {
            let db = dense.next_batch(2).unwrap();
            let sb = sparse.next_batch_csr(2).unwrap();
            match (db, sb) {
                (None, None) => break,
                (Some(db), Some(sb)) => {
                    // Densified CSR chunk == the densifying source's
                    // chunk, exactly (same parse, same overwrite order).
                    assert_eq!(sb.to_dense(), db);
                }
                (d, s) => {
                    panic!("sources fell out of step: {:?} vs {:?}", d.is_some(), s.is_some())
                }
            }
        }
        assert_eq!(sparse.rows_read(), dense.rows_read());
        assert_eq!(sparse.nnz_read(), 7, "feature 9 capped away, 7 entries survive");
    }

    #[test]
    fn default_next_batch_csr_sparsifies_dense_chunks() {
        // The provided-method path every dense source gets for free.
        let ds = synth::gaussian_blobs(30, 4, 2, 3.0, 21);
        let mut src = MatrixSource::from_dataset(&ds);
        let csr = src.next_batch_csr(12).unwrap().unwrap();
        assert_eq!(csr.rows(), 12);
        assert_eq!(csr.to_dense(), ds.points.row_block(0, 12));
        // And the sparse source's dense view round-trips the same rows.
        let dir = std::env::temp_dir().join("vivaldi_stream_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("sparse_rt.libsvm");
        crate::data::libsvm::write_libsvm(&path, &ds).unwrap();
        let mut ssrc = SparseLibsvmSource::open(&path, 4).unwrap();
        let mut chunks = Vec::new();
        while let Some(b) = ssrc.next_batch(7).unwrap() {
            chunks.push(b);
        }
        let whole = crate::data::libsvm::read_libsvm(&path, None, Some(4)).unwrap();
        assert_eq!(DenseMatrix::vstack(&chunks), whole.points);
    }
}
